//! The paper's five synthetic 2-D shapes (Fig. 5): Two Bananas, Smiling
//! Face, Concentric Circles, Circles & Gaussians, Flower — all nonlinearly
//! separable, which is what defeats k-means/EulerSC in Tables 4–5.
//! Generation is O(N) and threaded; shapes are deterministic per seed.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::par;
use crate::util::rng::Rng;

use std::f64::consts::PI;

/// Helper: fill an n×2 dataset in parallel using a per-chunk forked RNG.
/// `f(rng, t) -> (x, y, label)` where t ∈ [0,1) is the object's quantile
/// (gives deterministic class proportions regardless of thread count).
fn gen2d(name: &str, n: usize, seed: u64, f: impl Fn(&mut Rng, f64) -> (f64, f64, u32) + Sync) -> Dataset {
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0u32; n];
    // generate coordinates chunk-parallel
    let chunk = 8192;
    let coords: Vec<(f32, f32, u32)> = {
        let nchunks = n.div_ceil(chunk);
        let per_chunk: Vec<Vec<(f32, f32, u32)>> = par::par_map(nchunks, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            let mut rng = Rng::new(seed ^ (ci as u64).wrapping_mul(0xA24BAED4963EE407));
            (lo..hi)
                .map(|i| {
                    let t = i as f64 / n as f64;
                    let (a, b, l) = f(&mut rng, t);
                    (a as f32, b as f32, l)
                })
                .collect()
        });
        per_chunk.into_iter().flatten().collect()
    };
    for (i, (a, b, l)) in coords.into_iter().enumerate() {
        x.set(i, 0, a);
        x.set(i, 1, b);
        y[i] = l;
    }
    Dataset::new(name, x, y)
}

/// *Two Bananas* (TB): two interleaved crescents, 2 classes.
pub fn two_bananas(n: usize, seed: u64) -> Dataset {
    gen2d("TB", n, seed, |rng, t| {
        let label = if t < 0.5 { 0u32 } else { 1u32 };
        let theta = rng.f64() * PI;
        let noise = 0.08;
        let (cx, cy, flip) = if label == 0 { (0.0, 0.0, 1.0) } else { (1.0, 0.35, -1.0) };
        let x = cx + theta.cos() * flip + rng.normal() * noise;
        let y = cy + theta.sin() * flip + rng.normal() * noise;
        (x, y, label)
    })
}

/// Alias used in docs/tests: classic two-moons with parameterized noise.
pub fn two_moons(n: usize, noise: f64, seed: u64) -> Dataset {
    gen2d("moons", n, seed, |rng, t| {
        let label = if t < 0.5 { 0u32 } else { 1u32 };
        let theta = rng.f64() * PI;
        if label == 0 {
            (theta.cos() + rng.normal() * noise, theta.sin() + rng.normal() * noise, 0)
        } else {
            (
                1.0 - theta.cos() + rng.normal() * noise,
                0.5 - theta.sin() + rng.normal() * noise,
                1,
            )
        }
    })
}

/// *Smiling Face* (SF): face outline ring + two eye blobs + mouth arc,
/// 4 classes.
pub fn smiling_face(n: usize, seed: u64) -> Dataset {
    gen2d("SF", n, seed, |rng, t| {
        if t < 0.40 {
            // face outline: full circle radius 1
            let theta = rng.f64() * 2.0 * PI;
            (theta.cos() + rng.normal() * 0.025, theta.sin() + rng.normal() * 0.025, 0)
        } else if t < 0.55 {
            // left eye
            (-0.35 + rng.normal() * 0.06, 0.35 + rng.normal() * 0.06, 1)
        } else if t < 0.70 {
            // right eye
            (0.35 + rng.normal() * 0.06, 0.35 + rng.normal() * 0.06, 2)
        } else {
            // mouth: lower arc
            let theta = PI * (1.15 + 0.7 * rng.f64());
            (0.55 * theta.cos() + rng.normal() * 0.025, 0.25 + 0.55 * theta.sin() + rng.normal() * 0.025, 3)
        }
    })
}

/// *Concentric Circles* (CC): three rings, 3 classes.
pub fn concentric_circles(n: usize, seed: u64) -> Dataset {
    gen2d("CC", n, seed, |rng, t| {
        let label = if t < 1.0 / 3.0 {
            0u32
        } else if t < 2.0 / 3.0 {
            1
        } else {
            2
        };
        let r = [0.4, 1.0, 1.6][label as usize];
        let theta = rng.f64() * 2.0 * PI;
        (r * theta.cos() + rng.normal() * 0.04, r * theta.sin() + rng.normal() * 0.04, label)
    })
}

/// *Circles and Gaussians* (CG): 3 concentric rings around (-2, 0) plus a
/// 2nd double-ring at (2.5, 0) plus 6 Gaussian blobs = 11 classes.
pub fn circles_and_gaussians(n: usize, seed: u64) -> Dataset {
    // class proportions: rings heavier than blobs
    let blob_centers = [
        (-2.0, 3.0),
        (0.0, 3.2),
        (2.0, 3.0),
        (-1.0, -3.0),
        (1.0, -3.2),
        (3.5, -2.5),
    ];
    gen2d("CG", n, seed, |rng, t| {
        if t < 0.45 {
            // 3 rings at (-2, 0)
            let which = (t / 0.15) as usize;
            let r = [0.4, 0.9, 1.4][which.min(2)];
            let theta = rng.f64() * 2.0 * PI;
            (
                -2.0 + r * theta.cos() + rng.normal() * 0.035,
                r * theta.sin() + rng.normal() * 0.035,
                which.min(2) as u32,
            )
        } else if t < 0.70 {
            // 2 rings at (2.5, 0)
            let which = ((t - 0.45) / 0.125) as usize;
            let r = [0.5, 1.1][which.min(1)];
            let theta = rng.f64() * 2.0 * PI;
            (
                2.5 + r * theta.cos() + rng.normal() * 0.035,
                r * theta.sin() + rng.normal() * 0.035,
                3 + which.min(1) as u32,
            )
        } else {
            let which = (((t - 0.70) / 0.05) as usize).min(5);
            let (cx, cy) = blob_centers[which];
            (
                cx + rng.normal() * 0.22,
                cy + rng.normal() * 0.22,
                5 + which as u32,
            )
        }
    })
}

/// *Flower*: a center disc, a stem arc, a surrounding ring, and 10 petal
/// blobs = 13 classes.
pub fn flower(n: usize, seed: u64) -> Dataset {
    gen2d("Flower", n, seed, |rng, t| {
        if t < 0.18 {
            // center disc
            let r = 0.45 * rng.f64().sqrt();
            let theta = rng.f64() * 2.0 * PI;
            (r * theta.cos(), r * theta.sin(), 0)
        } else if t < 0.36 {
            // outer ring
            let theta = rng.f64() * 2.0 * PI;
            (2.2 * theta.cos() + rng.normal() * 0.04, 2.2 * theta.sin() + rng.normal() * 0.04, 1)
        } else if t < 0.50 {
            // stem arc below
            let theta = PI * (1.25 + 0.5 * rng.f64());
            (
                1.2 * theta.cos() + rng.normal() * 0.04,
                -2.4 + 1.2 * theta.sin() + rng.normal() * 0.04,
                2,
            )
        } else {
            // 10 petals between center and ring
            let which = (((t - 0.50) / 0.05) as usize).min(9);
            let ang = 2.0 * PI * which as f64 / 10.0;
            (
                1.3 * ang.cos() + rng.normal() * 0.10,
                1.3 * ang.sin() + rng.normal() * 0.10,
                3 + which as u32,
            )
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class_counts(y: &[u32], k: usize) -> Vec<usize> {
        let mut c = vec![0usize; k];
        for &l in y {
            c[l as usize] += 1;
        }
        c
    }

    #[test]
    fn shapes_and_classes() {
        let cases: Vec<(Dataset, usize)> = vec![
            (two_bananas(3000, 1), 2),
            (smiling_face(3000, 2), 4),
            (concentric_circles(3000, 3), 3),
            (circles_and_gaussians(5000, 4), 11),
            (flower(5000, 5), 13),
        ];
        for (ds, k) in cases {
            assert_eq!(ds.k, k, "{}", ds.name);
            assert_eq!(ds.d(), 2);
            let counts = class_counts(&ds.y, k);
            assert!(counts.iter().all(|&c| c > 0), "{}: empty class {counts:?}", ds.name);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = two_bananas(500, 9);
        let b = two_bananas(500, 9);
        assert_eq!(a.x.data, b.x.data);
        let c = two_bananas(500, 10);
        assert_ne!(a.x.data, c.x.data);
    }

    #[test]
    fn bananas_not_linearly_separable_by_kmeans() {
        // k-means should do poorly on TB while the classes are balanced —
        // this is the paper's core motivation (Table 4: TB-1M k-means NMI≈26%).
        let ds = two_bananas(4000, 11);
        let res = crate::kmeans::kmeans(
            &ds.x,
            &crate::kmeans::KmeansParams { k: 2, ..Default::default() },
            3,
        )
        .unwrap();
        let nmi = crate::metrics::nmi(&res.labels, &ds.y);
        assert!(nmi < 0.7, "k-means should not solve TB, nmi={nmi}");
    }

    #[test]
    fn rings_radii_sane() {
        let ds = concentric_circles(3000, 12);
        for i in 0..ds.n() {
            let r = (ds.x.at(i, 0).powi(2) + ds.x.at(i, 1).powi(2)).sqrt();
            let want = [0.4f32, 1.0, 1.6][ds.y[i] as usize];
            assert!((r - want).abs() < 0.35, "r={r} want≈{want}");
        }
    }
}
