//! Loaders for user-supplied data: dense CSV (features..., label) and
//! LIBSVM sparse text, plus a writer used by `repro fig5` / `gen-data`.

use super::Dataset;
use crate::linalg::Mat;
use crate::{Error, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Load a CSV where each line is `f1,f2,...,fd,label`. Lines starting with
/// `#` and blank lines are skipped. Labels may be arbitrary integers; they
/// are densified to 0..k-1.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut data: Vec<f32> = Vec::new();
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut d: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
        if fields.len() < 2 {
            return Err(Error::InvalidArg(format!("csv line {}: need >=2 fields", lineno + 1)));
        }
        let dd = fields.len() - 1;
        match d {
            None => d = Some(dd),
            Some(prev) if prev != dd => {
                return Err(Error::InvalidArg(format!(
                    "csv line {}: {} features, expected {}",
                    lineno + 1,
                    dd,
                    prev
                )))
            }
            _ => {}
        }
        for f in &fields[..dd] {
            data.push(f.parse::<f32>().map_err(|e| {
                Error::InvalidArg(format!("csv line {}: bad float '{}': {}", lineno + 1, f, e))
            })?);
        }
        raw_labels.push(fields[dd].parse::<i64>().map_err(|e| {
            Error::InvalidArg(format!("csv line {}: bad label: {}", lineno + 1, e))
        })?);
    }
    let d = d.ok_or_else(|| Error::InvalidArg("empty csv".into()))?;
    let n = raw_labels.len();
    // densify labels
    let mut map = std::collections::BTreeMap::new();
    for &l in &raw_labels {
        let next = map.len() as u32;
        map.entry(l).or_insert(next);
    }
    let y: Vec<u32> = raw_labels.iter().map(|l| map[l]).collect();
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("csv").to_string();
    Ok(Dataset::new(name, Mat::from_vec(n, d, data), y))
}

/// Write a dataset as CSV (inverse of [`load_csv`]).
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.n() {
        let row = ds.x.row(i);
        for v in row {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", ds.y[i])?;
    }
    w.flush()?;
    Ok(())
}

/// Load LIBSVM format: `label idx:val idx:val ...` (1-based indices).
/// `dim` pads/validates the feature count; pass 0 to infer from max index.
pub fn load_libsvm(path: &Path, dim: usize) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<(i64, Vec<(usize, f32)>)> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: i64 = parts
            .next()
            .unwrap()
            .parse()
            .map_err(|e| Error::InvalidArg(format!("libsvm line {}: label: {}", lineno + 1, e)))?;
        let mut feats = Vec::new();
        for p in parts {
            let (i, v) = p
                .split_once(':')
                .ok_or_else(|| Error::InvalidArg(format!("libsvm line {}: bad pair '{}'", lineno + 1, p)))?;
            let i: usize = i
                .parse()
                .map_err(|e| Error::InvalidArg(format!("libsvm line {}: idx: {}", lineno + 1, e)))?;
            let v: f32 = v
                .parse()
                .map_err(|e| Error::InvalidArg(format!("libsvm line {}: val: {}", lineno + 1, e)))?;
            if i == 0 {
                return Err(Error::InvalidArg(format!("libsvm line {}: 1-based idx", lineno + 1)));
            }
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        rows.push((label, feats));
    }
    let d = if dim > 0 { dim } else { max_idx };
    if max_idx > d {
        return Err(Error::InvalidArg(format!("libsvm: index {max_idx} > dim {d}")));
    }
    let n = rows.len();
    let mut data = vec![0.0f32; n * d];
    let mut map = std::collections::BTreeMap::new();
    let mut y = Vec::with_capacity(n);
    for (r, (label, feats)) in rows.into_iter().enumerate() {
        let next = map.len() as u32;
        y.push(*map.entry(label).or_insert(next));
        for (i, v) in feats {
            data[r * d + i] = v;
        }
    }
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("libsvm").to_string();
    Ok(Dataset::new(name, Mat::from_vec(n, d, data), y))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str, contents: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("uspec_test_{name}_{}", std::process::id()));
        std::fs::write(&p, contents).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let ds = crate::data::synthetic::two_moons(50, 0.05, 1);
        let p = std::env::temp_dir().join(format!("uspec_rt_{}.csv", std::process::id()));
        save_csv(&ds, &p).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(back.n(), 50);
        assert_eq!(back.d(), 2);
        assert_eq!(back.y, ds.y);
        for (a, b) in back.x.data.iter().zip(&ds.x.data) {
            assert!((a - b).abs() < 1e-4);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("ragged", "1.0,2.0,0\n1.0,1\n");
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_skips_comments() {
        let p = tmpfile("comments", "# header\n1.0,2.0,5\n\n3.0,4.0,9\n");
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.y, vec![0, 1]); // densified from 5, 9
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn libsvm_parses() {
        let p = tmpfile("libsvm", "1 1:0.5 3:2.0\n-1 2:1.5\n");
        let ds = load_libsvm(&p, 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.x.at(0, 0), 0.5);
        assert_eq!(ds.x.at(0, 2), 2.0);
        assert_eq!(ds.x.at(1, 1), 1.5);
        assert_eq!(ds.k, 2);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn libsvm_dim_check() {
        let p = tmpfile("libsvm_dim", "1 5:1.0\n");
        assert!(load_libsvm(&p, 3).is_err());
        std::fs::remove_file(p).ok();
    }
}
