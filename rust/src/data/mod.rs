//! Datasets: the paper's five synthetic shapes (Table 3 / Fig. 5), surrogate
//! generators matching the five real datasets' (N, d, #class) signatures,
//! and CSV/LIBSVM loaders for user data.

pub mod synthetic;
pub mod real_surrogate;
pub mod loader;

use crate::linalg::Mat;

/// A labeled dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// n×d feature matrix.
    pub x: Mat,
    /// Ground-truth labels (dense 0..k-1).
    pub y: Vec<u32>,
    /// Number of ground-truth classes.
    pub k: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<u32>) -> Dataset {
        assert_eq!(x.rows, y.len());
        let k = y.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
        Dataset { name: name.into(), x, y, k }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Random subsample of `n` objects (used for Fig. 5-style plots).
    pub fn subsample(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = crate::util::rng::Rng::new(seed);
        let idx = rng.sample_indices(self.n(), n.min(self.n()));
        Dataset::new(
            format!("{}-sub{}", self.name, n),
            self.x.gather_rows(&idx),
            idx.iter().map(|&i| self.y[i]).collect(),
        )
    }
}

/// The paper's benchmark inventory (Table 3). `scale` multiplies the
/// synthetic sizes (1.0 = the paper's ten-million-level sizes; the default
/// harness uses 0.01 — see DESIGN.md "Substitutions").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    PenDigits,
    Usps,
    Letters,
    Mnist,
    Covertype,
    Tb1m,
    Sf2m,
    Cc5m,
    Cg10m,
    Flower20m,
}

impl Benchmark {
    pub const ALL: [Benchmark; 10] = [
        Benchmark::PenDigits,
        Benchmark::Usps,
        Benchmark::Letters,
        Benchmark::Mnist,
        Benchmark::Covertype,
        Benchmark::Tb1m,
        Benchmark::Sf2m,
        Benchmark::Cc5m,
        Benchmark::Cg10m,
        Benchmark::Flower20m,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::PenDigits => "PenDigits",
            Benchmark::Usps => "USPS",
            Benchmark::Letters => "Letters",
            Benchmark::Mnist => "MNIST",
            Benchmark::Covertype => "Covertype",
            Benchmark::Tb1m => "TB-1M",
            Benchmark::Sf2m => "SF-2M",
            Benchmark::Cc5m => "CC-5M",
            Benchmark::Cg10m => "CG-10M",
            Benchmark::Flower20m => "Flower-20M",
        }
    }

    pub fn from_name(s: &str) -> Option<Benchmark> {
        Benchmark::ALL.iter().copied().find(|b| b.name().eq_ignore_ascii_case(s))
    }

    /// Paper-reported (N, d, #class).
    pub fn paper_shape(&self) -> (usize, usize, usize) {
        match self {
            Benchmark::PenDigits => (10_992, 16, 10),
            Benchmark::Usps => (11_000, 256, 10),
            Benchmark::Letters => (20_000, 16, 26),
            Benchmark::Mnist => (70_000, 784, 10),
            Benchmark::Covertype => (581_012, 54, 7),
            Benchmark::Tb1m => (1_000_000, 2, 2),
            Benchmark::Sf2m => (2_000_000, 2, 4),
            Benchmark::Cc5m => (5_000_000, 2, 3),
            Benchmark::Cg10m => (10_000_000, 2, 11),
            Benchmark::Flower20m => (20_000_000, 2, 13),
        }
    }

    pub fn is_synthetic(&self) -> bool {
        matches!(
            self,
            Benchmark::Tb1m
                | Benchmark::Sf2m
                | Benchmark::Cc5m
                | Benchmark::Cg10m
                | Benchmark::Flower20m
        )
    }

    /// Generate the dataset at `scale` × the paper size (clamped below so
    /// every generated set stays clusterable: ≥ max(100·k, 500) objects).
    pub fn generate(&self, scale: f64, seed: u64) -> Dataset {
        let (n_full, _d, k) = self.paper_shape();
        let n = ((n_full as f64 * scale) as usize).max(100 * k).max(500);
        match self {
            Benchmark::Tb1m => synthetic::two_bananas(n, seed),
            Benchmark::Sf2m => synthetic::smiling_face(n, seed),
            Benchmark::Cc5m => synthetic::concentric_circles(n, seed),
            Benchmark::Cg10m => synthetic::circles_and_gaussians(n, seed),
            Benchmark::Flower20m => synthetic::flower(n, seed),
            Benchmark::PenDigits => real_surrogate::surrogate(*self, n, seed),
            Benchmark::Usps => real_surrogate::surrogate(*self, n, seed),
            Benchmark::Letters => real_surrogate::surrogate(*self, n, seed),
            Benchmark::Mnist => real_surrogate::surrogate(*self, n, seed),
            Benchmark::Covertype => real_surrogate::surrogate(*self, n, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_matches_table3() {
        assert_eq!(Benchmark::Mnist.paper_shape(), (70_000, 784, 10));
        assert_eq!(Benchmark::Flower20m.paper_shape(), (20_000_000, 2, 13));
        assert_eq!(Benchmark::ALL.len(), 10);
    }

    #[test]
    fn generate_shapes() {
        for b in Benchmark::ALL {
            let ds = b.generate(0.001, 42);
            let (_, d, k) = b.paper_shape();
            assert_eq!(ds.d(), d, "{}", b.name());
            assert_eq!(ds.k, k, "{}", b.name());
            assert!(ds.n() >= (100 * k).max(500));
            // labels dense
            let maxl = *ds.y.iter().max().unwrap() as usize;
            assert_eq!(maxl + 1, k);
        }
    }

    #[test]
    fn from_name_roundtrip() {
        for b in Benchmark::ALL {
            assert_eq!(Benchmark::from_name(b.name()), Some(b));
        }
        assert_eq!(Benchmark::from_name("tb-1m"), Some(Benchmark::Tb1m));
        assert_eq!(Benchmark::from_name("nope"), None);
    }

    #[test]
    fn subsample_consistent() {
        let ds = Benchmark::Tb1m.generate(0.001, 1);
        let sub = ds.subsample(100, 2);
        assert_eq!(sub.n(), 100);
        assert_eq!(sub.d(), 2);
    }
}
