//! `repro` — the U-SPEC / U-SENC command-line leader.
//!
//! Examples:
//!   repro datasets
//!   repro cluster --dataset TB-1M --scale 0.01 --method U-SPEC --backend pjrt
//!   repro cluster --dataset CC-5M --method U-SENC --m 20 --workers 4
//!   repro table --id t4 --scale 0.001
//!   repro gen-data --dataset Flower-20M --scale 0.01 --out flower.csv
//!   repro serve-shard --data flower.bin --addr 0.0.0.0:7401
//!   repro stream --source remote://10.0.0.2:7401 --k 4 --shards 4
//!   repro serve --addr 0.0.0.0:7500 --models_dir models --queue 8
//!   repro fit --data train.bin --method u-spec --k 4 --out m.uspecmdl
//!   repro assign --data query.bin --model_file m.uspecmdl --out labels.txt

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match uspec::cli::parse(&args).and_then(uspec::cli::execute) {
        Ok(out) => {
            println!("{out}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    };
    std::process::exit(code);
}
