//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client via the
//! `xla` crate. Python never runs here — the artifacts are self-contained.
//!
//! * [`manifest`] — the artifact inventory (static shapes per variant).
//! * [`model`] — persisted fitted-model artifacts (versioned, checksummed
//!   binary format for U-SPEC/U-SENC models; [`save_model`]/[`load_model`]
//!   round-trip bit-exactly) backing out-of-sample assignment and the
//!   `repro serve` runtime.
//! * [`Runtime`] — compile-on-first-use executable cache + the padding
//!   machinery that maps arbitrary (rows, centers, d) requests onto the
//!   fixed-shape variants (rows → B-chunks, d → zero-padded columns,
//!   centers → padded rows masked or sliced away).
//! * [`pool`] — the kernel service thread + [`pool::PjrtBackend`], the
//!   [`crate::affinity::DistanceBackend`] the coordinator hands to U-SPEC.

pub mod manifest;
pub mod model;
pub mod pool;

pub use manifest::{ArtifactMeta, Manifest};
pub use model::{
    load_model, save_model, Model, UsencBase, UsencModel, UspecModel, MODEL_MAGIC, MODEL_VERSION,
};
pub use pool::{KernelPool, PjrtBackend};

use crate::linalg::Mat;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Default artifact directory: `$USPEC_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("USPEC_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The PJRT CPU runtime: one client, lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Counters for the perf report.
    pub dispatched: u64,
    pub rows_processed: u64,
}

impl Runtime {
    /// Load the manifest and initialize the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, exes: HashMap::new(), dispatched: 0, rows_processed: 0 })
    }

    /// Compile (or fetch cached) executable for an artifact.
    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let meta = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| Error::Runtime(format!("unknown artifact {name}")))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// True if a pdist variant covers (centers, d).
    pub fn covers(&self, c: usize, d: usize) -> bool {
        self.manifest.pick("pdist", c, d).is_some()
    }

    /// Full pairwise squared distances through the compiled Pallas kernel.
    /// Arbitrary `x.rows` (chunked over the static B), `c.rows`/`d` padded
    /// up to the chosen variant.
    pub fn pdist(&mut self, x: &Mat, c: &Mat) -> Result<Mat> {
        let meta = self
            .manifest
            .pick("pdist", c.rows, c.cols)
            .ok_or_else(|| {
                Error::Runtime(format!("no pdist artifact for c={} d={}", c.rows, c.cols))
            })?
            .clone();
        let (bv, cv, dv) = (meta.b, meta.c, meta.d);
        let n = x.rows;
        let cn = c.rows;
        let d = x.cols;
        debug_assert_eq!(c.cols, d);
        // centers padded once per call
        let cpad = pad_mat(c, cv, dv);
        let c_lit = xla::Literal::vec1(&cpad).reshape(&[cv as i64, dv as i64])?;
        let mut out = Mat::zeros(n, cn);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + bv).min(n);
            let rows = hi - lo;
            let xpad = pad_rows(&x.data[lo * d..hi * d], rows, d, bv, dv);
            let x_lit = xla::Literal::vec1(&xpad).reshape(&[bv as i64, dv as i64])?;
            let exe = self.exe(&meta.name)?;
            let result = exe.execute::<xla::Literal>(&[x_lit, c_lit.clone()])?[0][0]
                .to_literal_sync()?;
            let d2 = result.to_tuple1()?;
            let vals = d2.to_vec::<f32>()?; // bv × cv
            for r in 0..rows {
                let src = &vals[r * cv..r * cv + cn];
                out.data[(lo + r) * cn..(lo + r) * cn + cn].copy_from_slice(src);
            }
            self.dispatched += 1;
            self.rows_processed += rows as u64;
            lo = hi;
        }
        Ok(out)
    }

    /// Fused nearest-center (labels + min distance) through the compiled
    /// `dist_top1` graph. Centers beyond `c.rows` are masked invalid.
    pub fn dist_top1(&mut self, x: &Mat, c: &Mat) -> Result<(Vec<u32>, Vec<f32>)> {
        let meta = self
            .manifest
            .pick("dist_top1", c.rows, c.cols)
            .ok_or_else(|| {
                Error::Runtime(format!("no dist_top1 artifact for c={} d={}", c.rows, c.cols))
            })?
            .clone();
        let (bv, cv, dv) = (meta.b, meta.c, meta.d);
        let n = x.rows;
        let cn = c.rows;
        let d = x.cols;
        let cpad = pad_mat(c, cv, dv);
        let c_lit = xla::Literal::vec1(&cpad).reshape(&[cv as i64, dv as i64])?;
        let mut valid = vec![0f32; cv];
        for v in valid.iter_mut().take(cn) {
            *v = 1.0;
        }
        let v_lit = xla::Literal::vec1(&valid);
        let mut labels = vec![0u32; n];
        let mut dists = vec![0f32; n];
        let mut lo = 0;
        while lo < n {
            let hi = (lo + bv).min(n);
            let rows = hi - lo;
            let xpad = pad_rows(&x.data[lo * d..hi * d], rows, d, bv, dv);
            let x_lit = xla::Literal::vec1(&xpad).reshape(&[bv as i64, dv as i64])?;
            let exe = self.exe(&meta.name)?;
            let result = exe
                .execute::<xla::Literal>(&[x_lit, c_lit.clone(), v_lit.clone()])?[0][0]
                .to_literal_sync()?;
            let (idx, dist) = result.to_tuple2()?;
            let idx = idx.to_vec::<i32>()?;
            let dist = dist.to_vec::<f32>()?;
            for r in 0..rows {
                labels[lo + r] = idx[r] as u32;
                dists[lo + r] = dist[r];
            }
            self.dispatched += 1;
            self.rows_processed += rows as u64;
            lo = hi;
        }
        Ok((labels, dists))
    }
}

/// Pad an n×d matrix into padded_rows×padded_d (zero fill), row-major f32.
fn pad_mat(m: &Mat, padded_rows: usize, padded_d: usize) -> Vec<f32> {
    pad_rows(&m.data, m.rows, m.cols, padded_rows, padded_d)
}

fn pad_rows(data: &[f32], rows: usize, d: usize, padded_rows: usize, padded_d: usize) -> Vec<f32> {
    debug_assert!(rows <= padded_rows && d <= padded_d);
    let mut out = vec![0f32; padded_rows * padded_d];
    for r in 0..rows {
        out[r * padded_d..r * padded_d + d].copy_from_slice(&data[r * d..(r + 1) * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_layout() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = pad_mat(&m, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&p[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&p[8..12], &[0.0; 4]);
    }

    // Full runtime execution tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run).
}
