//! Kernel service thread + dynamic request batching.
//!
//! The PJRT client and its executables live on ONE dedicated service
//! thread (they are not `Sync`; single ownership also matches the paper's
//! single-accelerator deployment). Clustering workers — the m base
//! clusterers of U-SENC run concurrently by the coordinator — submit
//! [`Req`]s over an mpsc channel and block on their reply.
//!
//! **Dynamic batching** (the vLLM-router move, and the paper's "batch
//! processing manner" §3.1.4): the service thread drains whatever requests
//! are queued; consecutive `pdist` requests against the *same center set*
//! are coalesced into one padded kernel dispatch, amortizing the fixed
//! per-dispatch cost (literal building + PJRT launch) across requesters.

use super::Runtime;
use crate::affinity::DistanceBackend;
use crate::linalg::Mat;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

/// A kernel request.
enum Req {
    Pdist { x: Mat, c: Arc<Mat>, reply: Sender<Result<Mat>> },
    Top1 { x: Mat, c: Arc<Mat>, reply: Sender<Result<(Vec<u32>, Vec<f32>)>> },
    Stats { reply: Sender<(u64, u64)> },
    Shutdown,
}

/// Handle to the kernel service thread.
pub struct KernelPool {
    tx: Mutex<Sender<Req>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Requests answered by coalesced dispatches (perf counter).
    pub coalesced: AtomicU64,
}

impl KernelPool {
    /// Start the service thread over the artifact dir.
    pub fn start(dir: impl AsRef<std::path::Path>) -> Result<Arc<KernelPool>> {
        let dir = dir.as_ref().to_path_buf();
        // Fail fast on a missing manifest (on the caller's thread).
        let _probe = super::Manifest::load(&dir)?;
        let (tx, rx) = channel::<Req>();
        let pool = Arc::new(KernelPool {
            tx: Mutex::new(tx),
            handle: Mutex::new(None),
            coalesced: AtomicU64::new(0),
        });
        let pool2 = pool.clone();
        let handle = std::thread::Builder::new()
            .name("uspec-kernel-pool".into())
            .spawn(move || service_loop(dir, rx, pool2))
            .map_err(|e| Error::Runtime(format!("spawn kernel pool: {e}")))?;
        *pool.handle.lock().unwrap() = Some(handle);
        Ok(pool)
    }

    fn send(&self, req: Req) {
        // A dead service thread surfaces as a RecvError on the reply side.
        let _ = self.tx.lock().unwrap().send(req);
    }

    /// Squared distances via the compiled kernel (blocking).
    pub fn pdist(&self, x: Mat, c: Arc<Mat>) -> Result<Mat> {
        let (rtx, rrx) = channel();
        self.send(Req::Pdist { x, c, reply: rtx });
        rrx.recv().map_err(|_| Error::Runtime("kernel pool died".into()))?
    }

    /// Fused nearest-center via the compiled kernel (blocking).
    pub fn top1(&self, x: Mat, c: Arc<Mat>) -> Result<(Vec<u32>, Vec<f32>)> {
        let (rtx, rrx) = channel();
        self.send(Req::Top1 { x, c, reply: rtx });
        rrx.recv().map_err(|_| Error::Runtime("kernel pool died".into()))?
    }

    /// (dispatches, rows processed) since start.
    pub fn stats(&self) -> (u64, u64) {
        let (rtx, rrx) = channel();
        self.send(Req::Stats { reply: rtx });
        rrx.recv().unwrap_or((0, 0))
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Req::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn service_loop(dir: std::path::PathBuf, rx: Receiver<Req>, pool: Arc<KernelPool>) {
    let mut rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            // Drain requests with the load error until shutdown.
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Pdist { reply, .. } => {
                        let _ = reply.send(Err(Error::Runtime(format!("runtime load failed: {e}"))));
                    }
                    Req::Top1 { reply, .. } => {
                        let _ = reply.send(Err(Error::Runtime(format!("runtime load failed: {e}"))));
                    }
                    Req::Stats { reply } => {
                        let _ = reply.send((0, 0));
                    }
                    Req::Shutdown => return,
                }
            }
            return;
        }
    };
    let batch_rows = rt.manifest.batch;
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        match first {
            Req::Shutdown => return,
            Req::Stats { reply } => {
                let _ = reply.send((rt.dispatched, rt.rows_processed));
            }
            Req::Top1 { x, c, reply } => {
                let _ = reply.send(rt.dist_top1(&x, &c));
            }
            Req::Pdist { x, c, reply } => {
                // Coalesce: drain the queue for more pdist requests against
                // the same center set (Arc pointer equality — workers share
                // the Arc for a given rep set / neighborhood table).
                let mut xs = vec![x];
                let mut replies = vec![reply];
                let mut pending: Vec<Req> = Vec::new();
                loop {
                    match rx.try_recv() {
                        Ok(Req::Pdist { x: x2, c: c2, reply: r2 })
                            if Arc::ptr_eq(&c, &c2)
                                && xs.iter().map(|m| m.rows).sum::<usize>() + x2.rows
                                    <= batch_rows =>
                        {
                            xs.push(x2);
                            replies.push(r2);
                        }
                        Ok(other) => {
                            pending.push(other);
                            break;
                        }
                        Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                    }
                }
                if xs.len() == 1 {
                    let _ = replies.pop().unwrap().send(rt.pdist(&xs[0], &c));
                } else {
                    pool.coalesced.fetch_add(xs.len() as u64 - 1, Ordering::Relaxed);
                    // concat rows, one dispatch, split results
                    let d = xs[0].cols;
                    let total: usize = xs.iter().map(|m| m.rows).sum();
                    let mut big = Mat::zeros(total, d);
                    let mut off = 0;
                    for m in &xs {
                        big.data[off * d..(off + m.rows) * d].copy_from_slice(&m.data);
                        off += m.rows;
                    }
                    match rt.pdist(&big, &c) {
                        Ok(all) => {
                            let cn = c.rows;
                            let mut off = 0;
                            for (m, r) in xs.iter().zip(replies) {
                                let part = Mat {
                                    rows: m.rows,
                                    cols: cn,
                                    data: all.data[off * cn..(off + m.rows) * cn].to_vec(),
                                };
                                off += m.rows;
                                let _ = r.send(Ok(part));
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for r in replies {
                                let _ = r.send(Err(Error::Runtime(msg.clone())));
                            }
                        }
                    }
                }
                // process any request we pulled while coalescing
                for req in pending {
                    match req {
                        Req::Pdist { x, c, reply } => {
                            let _ = reply.send(rt.pdist(&x, &c));
                        }
                        Req::Top1 { x, c, reply } => {
                            let _ = reply.send(rt.dist_top1(&x, &c));
                        }
                        Req::Stats { reply } => {
                            let _ = reply.send((rt.dispatched, rt.rows_processed));
                        }
                        Req::Shutdown => return,
                    }
                }
            }
        }
    }
}

/// [`DistanceBackend`] backed by the kernel pool, with automatic native
/// fallback when no artifact covers the request shape (or when the block
/// is too small to amortize a dispatch).
pub struct PjrtBackend {
    pool: Arc<KernelPool>,
    /// Center sets larger than this (or d larger than the artifact grid)
    /// fall back to the native path.
    max_c: usize,
    max_d: usize,
    /// Blocks with fewer result cells than this run natively.
    pub min_cells: usize,
    /// Perf counters.
    pub kernel_calls: AtomicU64,
    pub native_calls: AtomicU64,
    /// Cache of the last center set seen (Arc identity enables coalescing).
    last_c: Mutex<Option<(u64, Arc<Mat>)>>,
}

impl PjrtBackend {
    pub fn new(pool: Arc<KernelPool>) -> PjrtBackend {
        PjrtBackend {
            pool,
            max_c: 256,
            max_d: 784,
            min_cells: 0,
            kernel_calls: AtomicU64::new(0),
            native_calls: AtomicU64::new(0),
            last_c: Mutex::new(None),
        }
    }

    /// Cheap content hash for center-set identity.
    fn hash_mat(m: &Mat) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        mix(m.rows as u64);
        mix(m.cols as u64);
        // sample up to 64 elements spread across the buffer
        let n = m.data.len();
        let step = (n / 64).max(1);
        for i in (0..n).step_by(step) {
            mix(m.data[i].to_bits() as u64);
        }
        h
    }

    fn shared_centers(&self, c: &Mat) -> Arc<Mat> {
        let h = Self::hash_mat(c);
        let mut guard = self.last_c.lock().unwrap();
        if let Some((ph, pc)) = guard.as_ref() {
            if *ph == h && pc.rows == c.rows && pc.cols == c.cols && pc.data == c.data {
                return pc.clone();
            }
        }
        let arc = Arc::new(c.clone());
        *guard = Some((h, arc.clone()));
        arc
    }
}

impl DistanceBackend for PjrtBackend {
    fn sq_dists(&self, x: &Mat, c: &Mat) -> Mat {
        let fits = c.rows <= self.max_c && c.cols <= self.max_d;
        let big_enough = x.rows * c.rows >= self.min_cells;
        if fits && big_enough {
            let carc = self.shared_centers(c);
            match self.pool.pdist(x.clone(), carc) {
                Ok(m) => {
                    self.kernel_calls.fetch_add(1, Ordering::Relaxed);
                    return m;
                }
                Err(_) => { /* fall through to native */ }
            }
        }
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        x.sq_dists(c)
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}
