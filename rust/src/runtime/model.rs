//! Persisted fitted-model artifacts — fit once, assign forever.
//!
//! A fitted U-SPEC run contains everything needed to label a *new* point
//! cheaply: the p representatives, a cluster label per representative,
//! and the Gaussian bandwidth σ (paper §4 — one packed-panel KNR query +
//! affinity vote per out-of-sample row). This module persists that state
//! as a versioned, checksummed binary artifact so a long-running service
//! ([`crate::net::serve`]) can load models fitted by earlier jobs and
//! answer assignment queries without refitting.
//!
//! # On-disk layout (little-endian throughout)
//!
//! ```text
//! magic    8 B   "USPECMDL"
//! version  1 B   MODEL_VERSION (currently 1)
//! kind     1 B   0 = U-SPEC, 1 = U-SENC ensemble
//! body     ...   kind-specific payload (below)
//! checksum 4 B   FNV-1a over everything before it (magic included)
//! ```
//!
//! U-SPEC body: `k u32 · k_nn u32 · seed u64 · sigma f64 · p u64 · d u64
//! · reps p×d f32 · rep_labels p×u32 · prov_len u32 · provenance JSON`.
//!
//! U-SENC body: `k u32 · seed u64 · m u32 · m× base · prov_len u32 ·
//! provenance JSON` where each base is a U-SPEC-shaped block (its own
//! `k`, `k_nn`, `sigma`, reps, rep_labels) followed by a `k × consensus_k`
//! u64 vote table counting fit-time (base label, consensus label)
//! co-occurrences — the consensus [`crate::pipeline::Pipeline::assign_consensus`]
//! vote weights.
//!
//! [`save_model`]/[`load_model`] round-trip bit-exactly (f32/f64 payloads
//! are stored as raw bit patterns). Loads reject corrupt, truncated, and
//! version-skewed files with typed [`crate::Error`]s before any field is
//! interpreted: magic and version first, then the trailing checksum over
//! the whole file, then structural validation of every length and label
//! range.

use crate::linalg::Mat;
use crate::net::proto::Fnv32;
use crate::{ensure_arg, Error, Result};
use std::path::Path;

/// Artifact file magic.
pub const MODEL_MAGIC: &[u8; 8] = b"USPECMDL";
/// Current artifact format version (the byte after the magic).
pub const MODEL_VERSION: u8 = 1;

const KIND_USPEC: u8 = 0;
const KIND_USENC: u8 = 1;

/// A fitted U-SPEC model: everything [`crate::pipeline::Pipeline::assign`]
/// needs to label out-of-sample rows bit-identically to the fit.
#[derive(Debug, Clone, PartialEq)]
pub struct UspecModel {
    /// Output cluster count (labels are in `0..k`).
    pub k: u32,
    /// Nearest representatives per assignment query.
    pub k_nn: u32,
    /// Pipeline seed the model was fitted with (provenance).
    pub seed: u64,
    /// Gaussian bandwidth σ from the fit's affinity stage.
    pub sigma: f64,
    /// The p×d representatives.
    pub reps: Mat,
    /// Cluster label per representative (majority vote of the fit points
    /// anchored on it; vote-less representatives inherit their nearest
    /// voted representative's label).
    pub rep_labels: Vec<u32>,
    /// Fit configuration provenance (compact JSON, informational).
    pub provenance: String,
}

/// One base clusterer of a fitted U-SENC ensemble.
#[derive(Debug, Clone, PartialEq)]
pub struct UsencBase {
    /// Base cluster count (rows of `votes`; base labels are in `0..k`).
    pub k: u32,
    pub k_nn: u32,
    pub sigma: f64,
    pub reps: Mat,
    pub rep_labels: Vec<u32>,
    /// `k × consensus_k` co-label counts from the fit: `votes[b*kc + c]`
    /// is how many fit points got base label `b` and consensus label `c`.
    pub votes: Vec<u64>,
}

/// A fitted U-SENC ensemble model for consensus assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct UsencModel {
    /// Consensus cluster count.
    pub k: u32,
    pub seed: u64,
    pub bases: Vec<UsencBase>,
    pub provenance: String,
}

/// A loaded model artifact of either kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Model {
    Uspec(UspecModel),
    Usenc(UsencModel),
}

impl Model {
    /// Artifact kind name ("uspec" / "usenc").
    pub fn kind(&self) -> &'static str {
        match self {
            Model::Uspec(_) => "uspec",
            Model::Usenc(_) => "usenc",
        }
    }

    /// Output cluster count (consensus k for ensembles).
    pub fn k(&self) -> u32 {
        match self {
            Model::Uspec(m) => m.k,
            Model::Usenc(m) => m.k,
        }
    }

    /// Feature dimension assignment inputs must have.
    pub fn d(&self) -> usize {
        match self {
            Model::Uspec(m) => m.reps.cols,
            Model::Usenc(m) => m.bases.first().map(|b| b.reps.cols).unwrap_or(0),
        }
    }
}

impl UspecModel {
    /// Structural validity: non-degenerate shapes and in-range labels.
    pub fn validate(&self) -> Result<()> {
        ensure_arg!(self.k >= 1, "model: k must be >= 1");
        ensure_arg!(self.k_nn >= 1, "model: k_nn must be >= 1");
        ensure_arg!(self.reps.rows >= 1, "model: empty representative set");
        ensure_arg!(
            self.rep_labels.len() == self.reps.rows,
            "model: {} rep labels for {} representatives",
            self.rep_labels.len(),
            self.reps.rows
        );
        ensure_arg!(
            self.rep_labels.iter().all(|&l| l < self.k),
            "model: representative label out of range (k={})",
            self.k
        );
        ensure_arg!(self.sigma > 0.0 && self.sigma.is_finite(), "model: bad sigma");
        Ok(())
    }
}

impl UsencModel {
    /// Structural validity of the ensemble: every base is a valid U-SPEC
    /// block with a `base.k × self.k` vote table, all on one dimension.
    pub fn validate(&self) -> Result<()> {
        ensure_arg!(self.k >= 1, "model: consensus k must be >= 1");
        ensure_arg!(!self.bases.is_empty(), "model: empty ensemble");
        let d = self.bases[0].reps.cols;
        for (i, b) in self.bases.iter().enumerate() {
            let as_uspec = UspecModel {
                k: b.k,
                k_nn: b.k_nn,
                seed: self.seed,
                sigma: b.sigma,
                reps: b.reps.clone(),
                rep_labels: b.rep_labels.clone(),
                provenance: String::new(),
            };
            as_uspec.validate().map_err(|e| Error::InvalidArg(format!("base {i}: {e}")))?;
            ensure_arg!(b.reps.cols == d, "model: base {i} dimension mismatch");
            ensure_arg!(
                b.votes.len() == b.k as usize * self.k as usize,
                "model: base {i} vote table is {} entries, want {}",
                b.votes.len(),
                b.k as usize * self.k as usize
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_mat(out: &mut Vec<u8>, m: &Mat) {
    out.extend_from_slice(&(m.rows as u64).to_le_bytes());
    out.extend_from_slice(&(m.cols as u64).to_le_bytes());
    for v in &m.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_uspec_block(out: &mut Vec<u8>, k: u32, k_nn: u32, seed: u64, sigma: f64, reps: &Mat, rep_labels: &[u32]) {
    out.extend_from_slice(&k.to_le_bytes());
    out.extend_from_slice(&k_nn.to_le_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    out.extend_from_slice(&sigma.to_bits().to_le_bytes());
    put_mat(out, reps);
    for l in rep_labels {
        out.extend_from_slice(&l.to_le_bytes());
    }
}

/// Serialize a model to the versioned, checksummed artifact byte layout.
pub fn encode_model(model: &Model) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(MODEL_MAGIC);
    out.push(MODEL_VERSION);
    match model {
        Model::Uspec(m) => {
            m.validate()?;
            out.push(KIND_USPEC);
            put_uspec_block(&mut out, m.k, m.k_nn, m.seed, m.sigma, &m.reps, &m.rep_labels);
            put_str(&mut out, &m.provenance);
        }
        Model::Usenc(m) => {
            m.validate()?;
            out.push(KIND_USENC);
            out.extend_from_slice(&m.k.to_le_bytes());
            out.extend_from_slice(&m.seed.to_le_bytes());
            out.extend_from_slice(&(m.bases.len() as u32).to_le_bytes());
            for b in &m.bases {
                put_uspec_block(&mut out, b.k, b.k_nn, m.seed, b.sigma, &b.reps, &b.rep_labels);
                for v in &b.votes {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            put_str(&mut out, &m.provenance);
        }
    }
    let mut fnv = Fnv32::new();
    fnv.update(&out);
    out.extend_from_slice(&fnv.finish().to_le_bytes());
    Ok(out)
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Byte cursor with typed truncation errors.
struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::InvalidArg(format!(
                "model artifact truncated reading {what} (need {n} bytes at offset {}, have {})",
                self.i,
                self.b.len() - self.i
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn mat(&mut self, what: &str) -> Result<Mat> {
        let rows = self.u64(what)? as usize;
        let cols = self.u64(what)? as usize;
        let count = rows
            .checked_mul(cols)
            .filter(|&c| c <= u32::MAX as usize)
            .ok_or_else(|| Error::InvalidArg(format!("model artifact: absurd {what} shape {rows}x{cols}")))?;
        let raw = self.take(count * 4, what)?;
        let mut data = Vec::with_capacity(count);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(Mat { rows, cols, data })
    }

    fn labels(&mut self, n: usize, what: &str) -> Result<Vec<u32>> {
        let raw = self.take(n * 4, what)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn string(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        let raw = self.take(len, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| Error::InvalidArg(format!("model artifact: {what} is not UTF-8")))
    }
}

fn uspec_block(d: &mut Dec, seed_override: Option<u64>) -> Result<(u32, u32, u64, f64, Mat, Vec<u32>)> {
    let k = d.u32("k")?;
    let k_nn = d.u32("k_nn")?;
    let seed = d.u64("seed")?;
    let sigma = d.f64("sigma")?;
    let reps = d.mat("representatives")?;
    let rep_labels = d.labels(reps.rows, "representative labels")?;
    Ok((k, k_nn, seed_override.unwrap_or(seed), sigma, reps, rep_labels))
}

/// Deserialize a model artifact, rejecting corrupt/truncated/version-skewed
/// bytes with typed errors. The checksum is verified before any field is
/// interpreted.
pub fn decode_model(bytes: &[u8]) -> Result<Model> {
    ensure_arg!(
        bytes.len() >= MODEL_MAGIC.len() + 2 + 4,
        "model artifact truncated ({} bytes, header alone is {})",
        bytes.len(),
        MODEL_MAGIC.len() + 2 + 4
    );
    ensure_arg!(
        &bytes[..MODEL_MAGIC.len()] == MODEL_MAGIC,
        "model artifact: bad magic (not a USPECMDL file)"
    );
    let version = bytes[MODEL_MAGIC.len()];
    ensure_arg!(
        version == MODEL_VERSION,
        "model artifact: unsupported version {version} (this build reads version {MODEL_VERSION})"
    );
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    let mut fnv = Fnv32::new();
    fnv.update(body);
    let computed = fnv.finish();
    ensure_arg!(
        stored == computed,
        "model artifact: checksum mismatch (stored {stored:#010x}, computed {computed:#010x}) — corrupt file"
    );
    let kind = bytes[MODEL_MAGIC.len() + 1];
    let mut d = Dec { b: body, i: MODEL_MAGIC.len() + 2 };
    let model = match kind {
        KIND_USPEC => {
            let (k, k_nn, seed, sigma, reps, rep_labels) = uspec_block(&mut d, None)?;
            let provenance = d.string("provenance")?;
            Model::Uspec(UspecModel { k, k_nn, seed, sigma, reps, rep_labels, provenance })
        }
        KIND_USENC => {
            let k = d.u32("consensus k")?;
            let seed = d.u64("seed")?;
            let m = d.u32("ensemble size")? as usize;
            ensure_arg!(m >= 1 && m <= 1 << 20, "model artifact: absurd ensemble size {m}");
            let mut bases = Vec::with_capacity(m);
            for _ in 0..m {
                let (bk, k_nn, _seed, sigma, reps, rep_labels) = uspec_block(&mut d, Some(seed))?;
                let nv = (bk as usize)
                    .checked_mul(k as usize)
                    .filter(|&c| c <= u32::MAX as usize)
                    .ok_or_else(|| {
                        Error::InvalidArg("model artifact: absurd vote table shape".into())
                    })?;
                let raw = d.take(nv * 8, "vote table")?;
                let votes = raw
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                bases.push(UsencBase { k: bk, k_nn, sigma, reps, rep_labels, votes });
            }
            let provenance = d.string("provenance")?;
            Model::Usenc(UsencModel { k, seed, bases, provenance })
        }
        other => {
            return Err(Error::InvalidArg(format!("model artifact: unknown kind byte {other}")))
        }
    };
    ensure_arg!(d.i == body.len(), "model artifact: {} trailing bytes", body.len() - d.i);
    match &model {
        Model::Uspec(m) => m.validate()?,
        Model::Usenc(m) => m.validate()?,
    }
    Ok(model)
}

/// Persist a model artifact. The write goes through a same-directory temp
/// file + rename so a concurrent [`load_model`] never observes a torn file.
pub fn save_model(path: impl AsRef<Path>, model: &Model) -> Result<()> {
    let path = path.as_ref();
    let bytes = encode_model(model)?;
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a model artifact saved by [`save_model`].
pub fn load_model(path: impl AsRef<Path>) -> Result<Model> {
    let bytes = std::fs::read(path.as_ref())?;
    decode_model(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_uspec() -> UspecModel {
        UspecModel {
            k: 2,
            k_nn: 3,
            seed: 42,
            sigma: 0.731,
            reps: Mat::from_vec(4, 2, vec![0.0, 1.0, 2.0, 3.0, -4.0, 5.5, 6.0, 7.25]),
            rep_labels: vec![0, 1, 1, 0],
            provenance: r#"{"algo":"uspec","k":2}"#.into(),
        }
    }

    fn sample_usenc() -> UsencModel {
        let b0 = UsencBase {
            k: 3,
            k_nn: 2,
            sigma: 1.5,
            reps: Mat::from_vec(3, 2, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]),
            rep_labels: vec![0, 1, 2],
            votes: vec![5, 0, 1, 6, 0, 7],
        };
        let b1 = UsencBase {
            k: 2,
            k_nn: 2,
            sigma: 0.25,
            reps: Mat::from_vec(2, 2, vec![0.5, 0.5, 1.5, 1.5]),
            rep_labels: vec![1, 0],
            votes: vec![3, 4, 9, 0],
        };
        UsencModel { k: 2, seed: 7, bases: vec![b0, b1], provenance: "{}".into() }
    }

    #[test]
    fn uspec_roundtrip_is_bit_exact() {
        let m = sample_uspec();
        let bytes = encode_model(&Model::Uspec(m.clone())).unwrap();
        let Model::Uspec(back) = decode_model(&bytes).unwrap() else { panic!("kind") };
        assert_eq!(back.sigma.to_bits(), m.sigma.to_bits());
        for (a, b) in back.reps.data.iter().zip(&m.reps.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back, m);
    }

    #[test]
    fn usenc_roundtrip_is_bit_exact() {
        let m = sample_usenc();
        let bytes = encode_model(&Model::Usenc(m.clone())).unwrap();
        let Model::Usenc(back) = decode_model(&bytes).unwrap() else { panic!("kind") };
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_corruption_truncation_and_skew() {
        let bytes = encode_model(&Model::Uspec(sample_uspec())).unwrap();
        // flip one payload byte → checksum mismatch
        let mut bad = bytes.clone();
        bad[20] ^= 0x01;
        let err = decode_model(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncated file
        let err = decode_model(&bytes[..bytes.len() - 9]).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("truncated"), "{err}");
        assert!(decode_model(&bytes[..4]).is_err());
        // version skew (checksum recomputed so the version check itself fires)
        let mut skew = bytes[..bytes.len() - 4].to_vec();
        skew[MODEL_MAGIC.len()] = MODEL_VERSION + 1;
        let mut fnv = Fnv32::new();
        fnv.update(&skew);
        skew.extend_from_slice(&fnv.finish().to_le_bytes());
        let err = decode_model(&skew).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // wrong magic
        let mut not_ours = bytes.clone();
        not_ours[0] = b'X';
        assert!(decode_model(&not_ours).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn file_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("uspec_model_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.uspecmdl");
        let m = Model::Usenc(sample_usenc());
        save_model(&path, &m).unwrap();
        assert_eq!(load_model(&path).unwrap(), m);
        // structurally invalid models are rejected at save time
        let mut bad = sample_uspec();
        bad.rep_labels[0] = 99;
        assert!(save_model(dir.join("bad.uspecmdl"), &Model::Uspec(bad)).is_err());
    }
}
