//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime. Lists every compiled HLO-text artifact with its
//! static shapes so the runtime can pick the smallest variant that fits a
//! request (padding rows/columns as needed).

use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// One AOT-compiled graph variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// Graph kind: "pdist" | "dist_top1" | "dist_topk".
    pub graph: String,
    /// File name (relative to the artifact dir).
    pub file: String,
    /// Static batch rows.
    pub b: usize,
    /// Static center rows.
    pub c: usize,
    /// Static feature dim.
    pub d: usize,
    /// top-k width (dist_topk only).
    pub k: Option<usize>,
    /// Number of tuple outputs.
    pub outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub fingerprint: String,
    pub batch: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| Error::Runtime(format!("manifest: {e}")))?;
        let fingerprint = v
            .get("fingerprint")
            .and_then(|f| f.as_str())
            .ok_or_else(|| Error::Runtime("manifest: missing fingerprint".into()))?
            .to_string();
        let batch = v
            .get("batch")
            .and_then(|b| b.as_usize())
            .ok_or_else(|| Error::Runtime("manifest: missing batch".into()))?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| Error::Runtime("manifest: missing artifacts".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::Runtime(format!("manifest: artifact missing {k}")))
            };
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| Error::Runtime(format!("manifest: artifact missing {k}")))
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                graph: get_str("graph")?,
                file: get_str("file")?,
                b: get_usize("b")?,
                c: get_usize("c")?,
                d: get_usize("d")?,
                k: a.get("k").and_then(|v| v.as_usize()),
                outputs: get_usize("outputs")?,
            });
        }
        Ok(Manifest { fingerprint, batch, artifacts })
    }

    /// Smallest pdist variant covering (c, d); None if nothing fits.
    pub fn pick(&self, graph: &str, c: usize, d: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.graph == graph && a.c >= c && a.d >= d)
            .min_by_key(|a| (a.c, a.d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc",
      "batch": 2048,
      "artifacts": [
        {"name": "pdist_b2048_c64_d2", "graph": "pdist", "file": "p.hlo.txt",
         "b": 2048, "c": 64, "d": 2, "k": null, "inputs": ["x","c"], "outputs": 1},
        {"name": "pdist_b2048_c256_d16", "graph": "pdist", "file": "q.hlo.txt",
         "b": 2048, "c": 256, "d": 16, "k": null, "inputs": ["x","c"], "outputs": 1},
        {"name": "dist_topk_b2048_c64_d2_k5", "graph": "dist_topk", "file": "t.hlo.txt",
         "b": 2048, "c": 64, "d": 2, "k": 5, "inputs": ["x","c","valid"], "outputs": 2}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 2048);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[2].k, Some(5));
    }

    #[test]
    fn pick_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.pick("pdist", 10, 2).unwrap();
        assert_eq!(a.c, 64);
        let b = m.pick("pdist", 100, 2).unwrap();
        assert_eq!(b.c, 256);
        assert!(m.pick("pdist", 300, 2).is_none());
        assert!(m.pick("pdist", 10, 999).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.pick("pdist", 64, 784).is_some());
            assert!(m.pick("dist_top1", 64, 2).is_some());
        }
    }
}
