//! Regenerates Table 12: quality/time vs ensemble size m for the ensemble
//! methods.
fn main() {
    uspec::bench::tables::bench_main(&["t12"], "t12_sweep_m");
}
