//! Regenerates Table 10: quality/time vs the number of representatives p
//! for Nyström, LSC-K/R, U-SPEC, U-SENC on the §4.5 datasets.
fn main() {
    uspec::bench::tables::bench_main(&["t10"], "t10_sweep_p");
}
