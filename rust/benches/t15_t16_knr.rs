//! Regenerates Tables 15–16: approximate vs exact K-nearest
//! representatives for U-SPEC and U-SENC (plus Fig. 3's recall sweep).
fn main() {
    uspec::bench::tables::bench_main(&["fig3", "t15-16"], "t15_t16_knr");
}
