//! Hot-path microbenchmarks (the §Perf instrumentation): persistent-pool
//! dispatch overhead vs spawn-per-call, the tiled packed distance kernel
//! vs the pre-tiling scalar reference, the runtime-dispatched SIMD tiles
//! vs the forced-scalar tiles, native vs PJRT pdist throughput, and the
//! approximate-KNR pipeline throughput.
//!
//! Prints GFLOP/s and rows/s; saves the text report to
//! `results/micro_hotpath.txt` and the machine-readable trajectory to
//! `BENCH_hotpath.json` at the repo root (before/after numbers are
//! measured in the same run so later PRs can track real deltas).

use std::sync::Arc;
use uspec::affinity::{knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bench::time_median;
use uspec::data::Benchmark;
use uspec::linalg::Mat;
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend, Runtime};
use uspec::util::par;
use uspec::util::rng::Rng;

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

fn gflops(n: usize, c: usize, d: usize, secs: f64) -> f64 {
    // ‖x‖²+‖c‖²−2xc: 2ncd flops dominate
    (2.0 * n as f64 * c as f64 * d as f64) / secs / 1e9
}

/// The pre-pool dispatch path: spawn + join fresh scoped threads per call
/// (verbatim shape of the old `par_map`) — the "before" of the worker-pool
/// change, measured in the same run.
fn spawn_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let nt = par::num_threads().min(n.max(1));
    if nt <= 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, slot) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = t * chunk;
                for (i, o) in slot.iter_mut().enumerate() {
                    *o = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// The pre-tiling distance kernel: 4-way j-unrolled scalar dot products
/// plus a separate epilogue pass (verbatim shape of the old
/// `matmul_nt`/`sq_dists`) — the "before" of the microkernel change.
fn sq_dists_reference(x: &Mat, c: &Mat) -> Mat {
    let m = x.rows;
    let n = c.rows;
    let d = x.cols;
    let xn: Vec<f32> = (0..m).map(|i| x.row(i).iter().map(|&v| v * v).sum()).collect();
    let cn: Vec<f32> = (0..n).map(|j| c.row(j).iter().map(|&v| v * v).sum()).collect();
    let mut out = Mat::zeros(m, n);
    par::par_for_chunks(&mut out.data, n * 64, |start, chunk| {
        let row0 = start / n;
        let nrows = chunk.len() / n;
        for bi in 0..nrows {
            let i = row0 + bi;
            let a = x.row(i);
            let orow = &mut chunk[bi * n..(bi + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let (b0, b1, b2, b3) = (c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
                for t in 0..d {
                    let av = a[t];
                    s0 += av * b0[t];
                    s1 += av * b1[t];
                    s2 += av * b2[t];
                    s3 += av * b3[t];
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                j += 4;
            }
            while j < n {
                let b = c.row(j);
                let mut s = 0.0f32;
                for t in 0..d {
                    s += a[t] * b[t];
                }
                orow[j] = s;
                j += 1;
            }
        }
    });
    par::par_for_chunks(&mut out.data, n, |start, chunk| {
        let i = start / n;
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = (xn[i] + cn[j] - 2.0 * *v).max(0.0);
        }
    });
    out
}

fn json_escape_free(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Pre-packed-kernel reduced eigensolve, verbatim shape of the old
// `bipartite::reduced_eig` fast path: serial Ŝ build, branchy per-element
// `matmul` with the `av == 0.0` skip, strided column-major Gram–Schmidt,
// and fresh `DMat`s allocated per Chebyshev term — the "before" of the f64
// kernel change, measured in the same run.
// ---------------------------------------------------------------------------

use uspec::bipartite::{reduced_eig_in, EigSolver};
use uspec::linalg::{eigen::sym_eig, DMat, EigScratch};

fn matmul_reference(a: &DMat, b: &DMat) -> DMat {
    let mut out = DMat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for (t, &av) in a.row(i).iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = b.row(t);
            let orow = out.row_mut(i);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn orthonormalize_reference(x: &mut DMat) -> Option<()> {
    let (n, b) = (x.rows, x.cols);
    for c in 0..b {
        for _pass in 0..2 {
            for prev in 0..c {
                let mut dot = 0.0;
                for r in 0..n {
                    dot += x.at(r, prev) * x.at(r, c);
                }
                for r in 0..n {
                    let v = x.at(r, c) - dot * x.at(r, prev);
                    x.set(r, c, v);
                }
            }
        }
        let norm: f64 = (0..n).map(|r| x.at(r, c) * x.at(r, c)).sum::<f64>().sqrt();
        if norm < 1e-13 {
            return None;
        }
        for r in 0..n {
            x.set(r, c, x.at(r, c) / norm);
        }
    }
    Some(())
}

fn subspace_iteration_reference(
    s: &DMat,
    k: usize,
    tol: f64,
    max_iter: usize,
    seed: u64,
) -> Option<(Vec<f64>, DMat)> {
    const DEG: usize = 8;
    let p = s.rows;
    let q = (k + 8).min(p);
    let mut rng = Rng::new(seed ^ 0x5B5);
    let mut x = DMat::zeros(p, q);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    orthonormalize_reference(&mut x)?;
    for _ in 0..4 {
        x = matmul_reference(s, &x);
        orthonormalize_reference(&mut x)?;
    }
    let ritz = |x: &DMat| -> Option<(Vec<f64>, DMat, Vec<f64>)> {
        let sx = matmul_reference(s, x);
        let mut h = matmul_reference(&x.transpose(), &sx);
        for i in 0..q {
            for j in 0..i {
                let v = 0.5 * (h.at(i, j) + h.at(j, i));
                h.set(i, j, v);
                h.set(j, i, v);
            }
        }
        let (hvals, hvecs) = sym_eig(&h).ok()?;
        let vals: Vec<f64> = (0..k).map(|c| hvals[q - 1 - c]).collect();
        let mut rot = DMat::zeros(q, k);
        for c in 0..k {
            for r in 0..q {
                rot.set(r, c, hvecs.at(r, q - 1 - c));
            }
        }
        Some((hvals, matmul_reference(x, &rot), vals))
    };
    let (mut hvals, _w0, mut prev_vals) = ritz(&x)?;
    let mut best: Option<(Vec<f64>, DMat, f64)> = None;
    let outer_max = (max_iter / DEG).max(4);
    for _it in 0..outer_max {
        let lam_kp1 = if q > k { hvals[q - 1 - k] } else { 0.5 };
        let lam_k = prev_vals[k - 1];
        let a = lam_kp1.clamp(1e-4, (lam_k * 0.999).max(1e-4));
        let apply_l = |y: &DMat| -> DMat {
            let mut sy = matmul_reference(s, y);
            let inv = 2.0 / a;
            for (o, v) in sy.data.iter_mut().zip(&y.data) {
                *o = *o * inv - *v;
            }
            sy
        };
        let mut z_prev = x.clone();
        let mut z = apply_l(&x);
        for _ in 2..=DEG {
            let mut z_next = apply_l(&z);
            for (o, v) in z_next.data.iter_mut().zip(&z_prev.data) {
                *o = 2.0 * *o - *v;
            }
            z_prev = z;
            z = z_next;
        }
        x = z;
        orthonormalize_reference(&mut x)?;
        let (nh, nw, nvals) = ritz(&x)?;
        hvals = nh;
        let delta: f64 =
            nvals.iter().zip(&prev_vals).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        prev_vals = nvals;
        if delta < tol {
            return Some((prev_vals, nw));
        }
        if best.as_ref().map(|(_, _, d)| delta < *d).unwrap_or(true) {
            best = Some((prev_vals.clone(), nw.clone(), delta));
        }
    }
    match best {
        Some((vals, w, delta)) if delta < 1e-4 => Some((vals, w)),
        _ => None,
    }
}

fn reduced_eig_reference(e_r: &DMat, k: usize, seed: u64) -> Option<(Vec<f64>, DMat)> {
    let p = e_r.rows;
    let d_r: Vec<f64> = (0..p).map(|i| e_r.row(i).iter().sum()).collect();
    let dis: Vec<f64> = d_r.iter().map(|&x| 1.0 / x.sqrt()).collect();
    let mut s = DMat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            s.set(i, j, e_r.at(i, j) * dis[i] * dis[j]);
        }
    }
    let (top_vals, w) = subspace_iteration_reference(&s, k, 1e-6, 150, seed)?;
    let vals: Vec<f64> = top_vals.iter().map(|&l| (1.0 - l).max(0.0)).collect();
    let mut v = DMat::zeros(p, k);
    for c in 0..k {
        for r in 0..p {
            v.set(r, c, w.at(r, c) * dis[r]);
        }
    }
    Some((vals, v))
}

/// Gaussian affinity over a 2-D three-cluster mixture: near-block-diagonal
/// with a clear eigengap, so the Chebyshev filter converges the same way
/// it does on the real rep-rep graphs.
fn clustered_affinity(p: usize, seed: u64) -> DMat {
    let mut rng = Rng::new(seed);
    let centers = [(0.0f64, 0.0f64), (6.0, 0.0), (0.0, 6.0)];
    let pts: Vec<(f64, f64)> = (0..p)
        .map(|i| {
            let (cx, cy) = centers[i % centers.len()];
            (cx + rng.normal(), cy + rng.normal())
        })
        .collect();
    let mut e_r = DMat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            let dx = pts[i].0 - pts[j].0;
            let dy = pts[i].1 - pts[j].1;
            e_r.set(i, j, (-(dx * dx + dy * dy) / 4.0).exp());
        }
    }
    e_r
}

fn main() {
    let mut out = String::new();
    let mut emit = |s: String| {
        println!("{s}");
        out.push_str(&s);
        out.push('\n');
    };
    let mut json_sections: Vec<String> = Vec::new();

    // ---- pool dispatch overhead: spawn-per-call vs persistent pool -------
    emit("== parallel-region dispatch overhead (spawn-per-call vs pool) ==".into());
    // warm the pool so one-time worker spawn is outside the measurement
    let _ = par::par_map(64, |i| i);
    let mut pool_rows: Vec<String> = Vec::new();
    for n in [16usize, 64, 256] {
        let reps = 200usize;
        let t_spawn = time_median(2, 5, || {
            for _ in 0..reps {
                std::hint::black_box(spawn_map(n, |i| i.wrapping_mul(3)));
            }
        }) / reps as f64;
        let t_pool = time_median(2, 5, || {
            for _ in 0..reps {
                std::hint::black_box(par::par_map(n, |i| i.wrapping_mul(3)));
            }
        }) / reps as f64;
        let speedup = t_spawn / t_pool;
        emit(format!(
            "dispatch n={n:4}: spawn {:8.2} µs   pool {:8.2} µs   speedup {:.1}x",
            t_spawn * 1e6,
            t_pool * 1e6,
            speedup
        ));
        pool_rows.push(format!(
            "{{\"n\": {n}, \"spawn_us\": {:.3}, \"pool_us\": {:.3}, \"speedup\": {:.2}}}",
            t_spawn * 1e6,
            t_pool * 1e6,
            json_escape_free(speedup)
        ));
    }
    json_sections.push(format!("\"pool_dispatch\": [{}]", pool_rows.join(", ")));

    // ---- sq_dists: tiled packed microkernel vs scalar reference ----------
    emit("\n== sq_dists at paper shapes (tiled packed vs scalar reference) ==".into());
    let mut sq_rows: Vec<String> = Vec::new();
    for (n, p, d) in [(4096usize, 1000usize, 10usize), (4096, 1000, 100)] {
        let x = randmat(n, d, 11);
        let cm = randmat(p, d, 12);
        let t_ref = time_median(1, 5, || {
            std::hint::black_box(sq_dists_reference(&x, &cm));
        });
        let t_tiled = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists(&cm));
        });
        // packed-reuse flavor: RHS packed once outside the timed region
        let packed = cm.pack_rhs();
        let t_packed = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let speedup = t_ref / t_tiled;
        emit(format!(
            "sq_dists n={n} p={p} d={d:3}: ref {:7.2} ms ({:6.2} GF/s)  tiled {:7.2} ms ({:6.2} GF/s)  packed-reuse {:7.2} ms  speedup {:.2}x",
            t_ref * 1e3,
            gflops(n, p, d, t_ref),
            t_tiled * 1e3,
            gflops(n, p, d, t_tiled),
            t_packed * 1e3,
            speedup
        ));
        sq_rows.push(format!(
            "{{\"n\": {n}, \"p\": {p}, \"d\": {d}, \"ref_ms\": {:.3}, \"tiled_ms\": {:.3}, \"packed_reuse_ms\": {:.3}, \"ref_gflops\": {:.2}, \"tiled_gflops\": {:.2}, \"speedup\": {:.2}}}",
            t_ref * 1e3,
            t_tiled * 1e3,
            t_packed * 1e3,
            gflops(n, p, d, t_ref),
            gflops(n, p, d, t_tiled),
            json_escape_free(speedup)
        ));
    }
    json_sections.push(format!("\"sq_dists\": [{}]", sq_rows.join(", ")));

    // ---- runtime SIMD dispatch vs forced-scalar tiles --------------------
    emit("\n== runtime SIMD dispatch (dispatched vs forced-scalar tiles) ==".into());
    let mut simd_rows: Vec<String> = Vec::new();
    for (n, p, d) in [(4096usize, 1000usize, 10usize), (4096, 1000, 100)] {
        let x = randmat(n, d, 21);
        let cm = randmat(p, d, 22);
        let packed = cm.pack_rhs();
        uspec::linalg::set_simd_override(1);
        let t_scalar = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let t_scalar_near = time_median(1, 5, || {
            std::hint::black_box(uspec::linalg::nearest_packed(&x, &packed));
        });
        let scalar_out = x.sq_dists_packed(&packed);
        uspec::linalg::set_simd_override(0);
        let t_simd = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let t_simd_near = time_median(1, 5, || {
            std::hint::black_box(uspec::linalg::nearest_packed(&x, &packed));
        });
        // the dispatch contract, re-checked where the numbers are made
        let simd_out = x.sq_dists_packed(&packed);
        assert!(
            scalar_out.data.iter().zip(&simd_out.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "scalar and dispatched kernels diverged"
        );
        emit(format!(
            "simd n={n} p={p} d={d:3}: scalar {:7.2} ms  dispatched {:7.2} ms ({:6.2} GF/s)  sq_dists {:.2}x  nearest {:.2}x",
            t_scalar * 1e3,
            t_simd * 1e3,
            gflops(n, p, d, t_simd),
            t_scalar / t_simd,
            t_scalar_near / t_simd_near
        ));
        simd_rows.push(format!(
            "{{\"n\": {n}, \"p\": {p}, \"d\": {d}, \"scalar_ms\": {:.3}, \"dispatched_ms\": {:.3}, \"scalar_nearest_ms\": {:.3}, \"dispatched_nearest_ms\": {:.3}, \"sq_dists_speedup\": {:.2}, \"nearest_speedup\": {:.2}}}",
            t_scalar * 1e3,
            t_simd * 1e3,
            t_scalar_near * 1e3,
            t_simd_near * 1e3,
            json_escape_free(t_scalar / t_simd),
            json_escape_free(t_scalar_near / t_simd_near)
        ));
    }
    json_sections.push(format!("\"simd_dispatch\": [{}]", simd_rows.join(", ")));

    // ---- reduced eigensolve: packed f64 gemm + scratch vs old scalar path -
    emit("\n== reduced_eig (packed f64 gemm + scratch vs old scalar path) ==".into());
    let mut eig_rows: Vec<String> = Vec::new();
    let mut scr = EigScratch::default();
    for (p, k) in [(400usize, 10usize), (1200, 10)] {
        let e_r = clustered_affinity(p, 31);
        let (ref_vals, _) = reduced_eig_reference(&e_r, k, 41).expect("reference solve");
        let t_ref = time_median(0, 3, || {
            std::hint::black_box(reduced_eig_reference(&e_r, k, 41).unwrap());
        });
        uspec::linalg::set_simd_override(1);
        let t_scalar = time_median(1, 3, || {
            std::hint::black_box(
                reduced_eig_in(&e_r, k, EigSolver::Auto, 41, &mut scr).unwrap(),
            );
        });
        let (lam_s, v_s) = reduced_eig_in(&e_r, k, EigSolver::Auto, 41, &mut scr).unwrap();
        uspec::linalg::set_simd_override(0);
        let t_simd = time_median(1, 3, || {
            std::hint::black_box(
                reduced_eig_in(&e_r, k, EigSolver::Auto, 41, &mut scr).unwrap(),
            );
        });
        let (lam_d, v_d) = reduced_eig_in(&e_r, k, EigSolver::Auto, 41, &mut scr).unwrap();
        // the dispatch contract, re-checked where the numbers are made:
        // forced-scalar and dispatched solves must be bit-identical
        assert!(
            lam_s.iter().zip(&lam_d).all(|(a, b)| a.to_bits() == b.to_bits())
                && v_s.data.iter().zip(&v_d.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "scalar and dispatched reduced_eig diverged"
        );
        // and the new path must agree with the old one numerically
        for (a, b) in lam_d.iter().zip(&ref_vals) {
            assert!((a - b).abs() < 1e-5, "reduced_eig drifted from reference: {a} vs {b}");
        }
        emit(format!(
            "reduced_eig p={p:4} k={k}: old {:8.2} ms  scalar {:7.2} ms  dispatched {:7.2} ms  speedup {:.2}x (simd {:.2}x)",
            t_ref * 1e3,
            t_scalar * 1e3,
            t_simd * 1e3,
            t_ref / t_simd,
            t_scalar / t_simd
        ));
        eig_rows.push(format!(
            "{{\"p\": {p}, \"k\": {k}, \"ref_ms\": {:.3}, \"scalar_ms\": {:.3}, \"dispatched_ms\": {:.3}, \"speedup\": {:.2}, \"simd_speedup\": {:.2}}}",
            t_ref * 1e3,
            t_scalar * 1e3,
            t_simd * 1e3,
            json_escape_free(t_ref / t_simd),
            json_escape_free(t_scalar / t_simd)
        ));
    }
    json_sections.push(format!("\"eig\": [{}]", eig_rows.join(", ")));

    // ---- native vs PJRT pdist throughput ---------------------------------
    emit("\n== pdist throughput (native vs PJRT artifact) ==".into());
    let shapes = [(8192usize, 64usize, 2usize), (8192, 64, 16), (8192, 256, 64), (4096, 256, 784)];
    let have_artifacts = default_artifact_dir().join("manifest.json").exists();
    let mut rt = if have_artifacts { Runtime::load(default_artifact_dir()).ok() } else { None };
    for (n, c, d) in shapes {
        let x = randmat(n, d, 1);
        let cm = randmat(c, d, 2);
        let t_native = time_median(1, 3, || {
            std::hint::black_box(x.sq_dists(&cm));
        });
        emit(format!(
            "native  n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s",
            t_native * 1e3,
            gflops(n, c, d, t_native)
        ));
        if let Some(rt) = rt.as_mut() {
            let t_pjrt = time_median(1, 3, || {
                std::hint::black_box(rt.pdist(&x, &cm).unwrap());
            });
            emit(format!(
                "pjrt    n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s  ({:.1}x native time)",
                t_pjrt * 1e3,
                gflops(n, c, d, t_pjrt),
                t_pjrt / t_native
            ));
        }
    }

    if have_artifacts {
        emit("\n== kernel pool dispatch overhead ==".into());
        let pool = KernelPool::start(default_artifact_dir()).unwrap();
        let c = Arc::new(randmat(64, 16, 3));
        for rows in [64usize, 512, 2048] {
            let x = randmat(rows, 16, 4);
            let t = time_median(2, 5, || {
                std::hint::black_box(pool.pdist(x.clone(), c.clone()).unwrap());
            });
            emit(format!(
                "pool pdist rows={rows:5}: {:8.3} ms ({:.0} rows/ms)",
                t * 1e3,
                rows as f64 / (t * 1e3)
            ));
        }
        let backend = PjrtBackend::new(pool);
        let ds = Benchmark::Tb1m.generate(0.01, 5); // 10k points
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 1000, 20, 7).unwrap();
        let t_knr = time_median(0, 2, || {
            let index = KnrIndex::build(&reps, 50, 20, &backend).unwrap();
            std::hint::black_box(index.approx_knr(&ds.x, 5, &backend));
        });
        emit(format!(
            "approx-KNR (pjrt)   n=10000 p=1000: {:7.1} ms ({:.0} objects/s)",
            t_knr * 1e3,
            10_000.0 / t_knr
        ));
    }

    // ---- approx/exact KNR pipeline throughput (native) -------------------
    emit("\n== approx-KNR pipeline throughput (native) ==".into());
    let mut knr_rows: Vec<String> = Vec::new();
    for scale in [0.01f64, 0.05] {
        let ds = Benchmark::Tb1m.generate(scale, 5);
        let p = 1000.min(ds.n() / 2);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, p, 20, 7).unwrap();
        let index = KnrIndex::build(&reps, 50, 20, &NativeBackend).unwrap();
        let t_a = time_median(0, 3, || {
            std::hint::black_box(index.approx_knr(&ds.x, 5, &NativeBackend));
        });
        let t_e = time_median(0, 3, || {
            std::hint::black_box(index.exact_knr(&ds.x, 5, &NativeBackend));
        });
        emit(format!(
            "n={:6} p={p:4}: approx {:7.1} ms ({:9.0} obj/s)  exact {:7.1} ms  speedup {:.1}x",
            ds.n(),
            t_a * 1e3,
            ds.n() as f64 / t_a,
            t_e * 1e3,
            t_e / t_a
        ));
        knr_rows.push(format!(
            "{{\"n\": {}, \"p\": {p}, \"approx_ms\": {:.2}, \"exact_ms\": {:.2}, \"approx_objs_per_s\": {:.0}}}",
            ds.n(),
            t_a * 1e3,
            t_e * 1e3,
            ds.n() as f64 / t_a
        ));
    }
    json_sections.push(format!("\"approx_knr\": [{}]", knr_rows.join(", ")));

    emit("\n== U-SPEC end-to-end (native) ==".into());
    let mut uspec_rows: Vec<String> = Vec::new();
    for scale in [0.01f64, 0.1] {
        let ds = Benchmark::Tb1m.generate(scale, 9);
        let params =
            uspec::uspec::UspecParams { k: 2, p: 1000.min(ds.n() / 2), ..Default::default() };
        let t = time_median(0, 1, || {
            std::hint::black_box(uspec::uspec::uspec(&ds.x, &params, 3).unwrap());
        });
        emit(format!(
            "U-SPEC n={:7}: {:8.2} s  ({:9.0} objects/s)",
            ds.n(),
            t,
            ds.n() as f64 / t
        ));
        uspec_rows.push(format!(
            "{{\"n\": {}, \"seconds\": {:.3}, \"objs_per_s\": {:.0}}}",
            ds.n(),
            t,
            ds.n() as f64 / t
        ));
    }
    json_sections.push(format!("\"uspec_end_to_end\": [{}]", uspec_rows.join(", ")));

    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/micro_hotpath.txt", &out);
    eprintln!("[saved results/micro_hotpath.txt]");

    // machine-readable perf trajectory at the repo root
    let json = format!(
        "{{\n  \"harness\": \"cargo-bench\",\n  \"threads\": {},\n  \"pool_dispatches\": {},\n  {}\n}}\n",
        par::num_threads(),
        par::pool_dispatch_count(),
        json_sections.join(",\n  ")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    match std::fs::write(&path, &json) {
        Ok(()) => eprintln!("[saved {}]", path.display()),
        Err(e) => eprintln!("[failed to save {}: {e}]", path.display()),
    }
}
