//! Hot-path microbenchmarks (the §Perf instrumentation): native vs PJRT
//! pdist throughput, kernel-pool dispatch overhead and coalescing, and the
//! approximate-KNR pipeline throughput. Prints GFLOP/s and rows/s; saved
//! to results/micro_hotpath.txt.

use std::sync::Arc;
use uspec::affinity::{knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bench::time_median;
use uspec::data::Benchmark;
use uspec::linalg::Mat;
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend, Runtime};
use uspec::util::rng::Rng;

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

fn gflops(n: usize, c: usize, d: usize, secs: f64) -> f64 {
    // ‖x‖²+‖c‖²−2xc: 2ncd flops dominate
    (2.0 * n as f64 * c as f64 * d as f64) / secs / 1e9
}

fn main() {
    let mut out = String::new();
    let mut emit = |s: String| {
        println!("{s}");
        out.push_str(&s);
        out.push('\n');
    };

    emit("== pdist throughput (native vs PJRT artifact) ==".into());
    let shapes = [(8192usize, 64usize, 2usize), (8192, 64, 16), (8192, 256, 64), (4096, 256, 784)];
    let have_artifacts = default_artifact_dir().join("manifest.json").exists();
    let mut rt = if have_artifacts { Runtime::load(default_artifact_dir()).ok() } else { None };
    for (n, c, d) in shapes {
        let x = randmat(n, d, 1);
        let cm = randmat(c, d, 2);
        let t_native = time_median(1, 3, || {
            std::hint::black_box(x.sq_dists(&cm));
        });
        emit(format!(
            "native  n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s",
            t_native * 1e3,
            gflops(n, c, d, t_native)
        ));
        if let Some(rt) = rt.as_mut() {
            let t_pjrt = time_median(1, 3, || {
                std::hint::black_box(rt.pdist(&x, &cm).unwrap());
            });
            emit(format!(
                "pjrt    n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s  ({:.1}x native time)",
                t_pjrt * 1e3,
                gflops(n, c, d, t_pjrt),
                t_pjrt / t_native
            ));
        }
    }

    if have_artifacts {
        emit("\n== kernel pool dispatch overhead ==".into());
        let pool = KernelPool::start(default_artifact_dir()).unwrap();
        let c = Arc::new(randmat(64, 16, 3));
        for rows in [64usize, 512, 2048] {
            let x = randmat(rows, 16, 4);
            let t = time_median(2, 5, || {
                std::hint::black_box(pool.pdist(x.clone(), c.clone()).unwrap());
            });
            emit(format!(
                "pool pdist rows={rows:5}: {:8.3} ms ({:.0} rows/ms)",
                t * 1e3,
                rows as f64 / (t * 1e3)
            ));
        }
        let backend = PjrtBackend::new(pool);
        let ds = Benchmark::Tb1m.generate(0.01, 5); // 10k points
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 1000, 20, 7).unwrap();
        let t_knr = time_median(0, 2, || {
            let index = KnrIndex::build(&reps, 50, 20, &backend).unwrap();
            std::hint::black_box(index.approx_knr(&ds.x, 5, &backend));
        });
        emit(format!(
            "approx-KNR (pjrt)   n=10000 p=1000: {:7.1} ms ({:.0} objects/s)",
            t_knr * 1e3,
            10_000.0 / t_knr
        ));
    }

    emit("\n== approx-KNR pipeline throughput (native) ==".into());
    for scale in [0.01f64, 0.05] {
        let ds = Benchmark::Tb1m.generate(scale, 5);
        let p = 1000.min(ds.n() / 2);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, p, 20, 7).unwrap();
        let index = KnrIndex::build(&reps, 50, 20, &NativeBackend).unwrap();
        let t_a = time_median(0, 3, || {
            std::hint::black_box(index.approx_knr(&ds.x, 5, &NativeBackend));
        });
        let t_e = time_median(0, 3, || {
            std::hint::black_box(index.exact_knr(&ds.x, 5, &NativeBackend));
        });
        emit(format!(
            "n={:6} p={p:4}: approx {:7.1} ms ({:9.0} obj/s)  exact {:7.1} ms  speedup {:.1}x",
            ds.n(),
            t_a * 1e3,
            ds.n() as f64 / t_a,
            t_e * 1e3,
            t_e / t_a
        ));
    }

    emit("\n== U-SPEC end-to-end (native) ==".into());
    for scale in [0.01f64, 0.1] {
        let ds = Benchmark::Tb1m.generate(scale, 9);
        let params =
            uspec::uspec::UspecParams { k: 2, p: 1000.min(ds.n() / 2), ..Default::default() };
        let t = time_median(0, 1, || {
            std::hint::black_box(uspec::uspec::uspec(&ds.x, &params, 3).unwrap());
        });
        emit(format!(
            "U-SPEC n={:7}: {:8.2} s  ({:9.0} objects/s)",
            ds.n(),
            t,
            ds.n() as f64 / t
        ));
    }

    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/micro_hotpath.txt", out);
    eprintln!("[saved results/micro_hotpath.txt]");
}
