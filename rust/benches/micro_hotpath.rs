//! Hot-path microbenchmarks (the §Perf instrumentation): persistent-pool
//! dispatch overhead vs spawn-per-call, the tiled packed distance kernel
//! vs the pre-tiling scalar reference, the runtime-dispatched SIMD tiles
//! vs the forced-scalar tiles, native vs PJRT pdist throughput, and the
//! approximate-KNR pipeline throughput.
//!
//! Prints GFLOP/s and rows/s; saves the text report to
//! `results/micro_hotpath.txt` and the machine-readable trajectory to
//! `BENCH_hotpath.json` at the repo root (before/after numbers are
//! measured in the same run so later PRs can track real deltas).

use std::sync::Arc;
use uspec::affinity::{knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bench::time_median;
use uspec::data::Benchmark;
use uspec::linalg::Mat;
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend, Runtime};
use uspec::util::par;
use uspec::util::rng::Rng;

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

fn gflops(n: usize, c: usize, d: usize, secs: f64) -> f64 {
    // ‖x‖²+‖c‖²−2xc: 2ncd flops dominate
    (2.0 * n as f64 * c as f64 * d as f64) / secs / 1e9
}

/// The pre-pool dispatch path: spawn + join fresh scoped threads per call
/// (verbatim shape of the old `par_map`) — the "before" of the worker-pool
/// change, measured in the same run.
fn spawn_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let nt = par::num_threads().min(n.max(1));
    if nt <= 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, slot) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = t * chunk;
                for (i, o) in slot.iter_mut().enumerate() {
                    *o = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// The pre-tiling distance kernel: 4-way j-unrolled scalar dot products
/// plus a separate epilogue pass (verbatim shape of the old
/// `matmul_nt`/`sq_dists`) — the "before" of the microkernel change.
fn sq_dists_reference(x: &Mat, c: &Mat) -> Mat {
    let m = x.rows;
    let n = c.rows;
    let d = x.cols;
    let xn: Vec<f32> = (0..m).map(|i| x.row(i).iter().map(|&v| v * v).sum()).collect();
    let cn: Vec<f32> = (0..n).map(|j| c.row(j).iter().map(|&v| v * v).sum()).collect();
    let mut out = Mat::zeros(m, n);
    par::par_for_chunks(&mut out.data, n * 64, |start, chunk| {
        let row0 = start / n;
        let nrows = chunk.len() / n;
        for bi in 0..nrows {
            let i = row0 + bi;
            let a = x.row(i);
            let orow = &mut chunk[bi * n..(bi + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let (b0, b1, b2, b3) = (c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
                for t in 0..d {
                    let av = a[t];
                    s0 += av * b0[t];
                    s1 += av * b1[t];
                    s2 += av * b2[t];
                    s3 += av * b3[t];
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                j += 4;
            }
            while j < n {
                let b = c.row(j);
                let mut s = 0.0f32;
                for t in 0..d {
                    s += a[t] * b[t];
                }
                orow[j] = s;
                j += 1;
            }
        }
    });
    par::par_for_chunks(&mut out.data, n, |start, chunk| {
        let i = start / n;
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = (xn[i] + cn[j] - 2.0 * *v).max(0.0);
        }
    });
    out
}

fn json_escape_free(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

fn main() {
    let mut out = String::new();
    let mut emit = |s: String| {
        println!("{s}");
        out.push_str(&s);
        out.push('\n');
    };
    let mut json_sections: Vec<String> = Vec::new();

    // ---- pool dispatch overhead: spawn-per-call vs persistent pool -------
    emit("== parallel-region dispatch overhead (spawn-per-call vs pool) ==".into());
    // warm the pool so one-time worker spawn is outside the measurement
    let _ = par::par_map(64, |i| i);
    let mut pool_rows: Vec<String> = Vec::new();
    for n in [16usize, 64, 256] {
        let reps = 200usize;
        let t_spawn = time_median(2, 5, || {
            for _ in 0..reps {
                std::hint::black_box(spawn_map(n, |i| i.wrapping_mul(3)));
            }
        }) / reps as f64;
        let t_pool = time_median(2, 5, || {
            for _ in 0..reps {
                std::hint::black_box(par::par_map(n, |i| i.wrapping_mul(3)));
            }
        }) / reps as f64;
        let speedup = t_spawn / t_pool;
        emit(format!(
            "dispatch n={n:4}: spawn {:8.2} µs   pool {:8.2} µs   speedup {:.1}x",
            t_spawn * 1e6,
            t_pool * 1e6,
            speedup
        ));
        pool_rows.push(format!(
            "{{\"n\": {n}, \"spawn_us\": {:.3}, \"pool_us\": {:.3}, \"speedup\": {:.2}}}",
            t_spawn * 1e6,
            t_pool * 1e6,
            json_escape_free(speedup)
        ));
    }
    json_sections.push(format!("\"pool_dispatch\": [{}]", pool_rows.join(", ")));

    // ---- sq_dists: tiled packed microkernel vs scalar reference ----------
    emit("\n== sq_dists at paper shapes (tiled packed vs scalar reference) ==".into());
    let mut sq_rows: Vec<String> = Vec::new();
    for (n, p, d) in [(4096usize, 1000usize, 10usize), (4096, 1000, 100)] {
        let x = randmat(n, d, 11);
        let cm = randmat(p, d, 12);
        let t_ref = time_median(1, 5, || {
            std::hint::black_box(sq_dists_reference(&x, &cm));
        });
        let t_tiled = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists(&cm));
        });
        // packed-reuse flavor: RHS packed once outside the timed region
        let packed = cm.pack_rhs();
        let t_packed = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let speedup = t_ref / t_tiled;
        emit(format!(
            "sq_dists n={n} p={p} d={d:3}: ref {:7.2} ms ({:6.2} GF/s)  tiled {:7.2} ms ({:6.2} GF/s)  packed-reuse {:7.2} ms  speedup {:.2}x",
            t_ref * 1e3,
            gflops(n, p, d, t_ref),
            t_tiled * 1e3,
            gflops(n, p, d, t_tiled),
            t_packed * 1e3,
            speedup
        ));
        sq_rows.push(format!(
            "{{\"n\": {n}, \"p\": {p}, \"d\": {d}, \"ref_ms\": {:.3}, \"tiled_ms\": {:.3}, \"packed_reuse_ms\": {:.3}, \"ref_gflops\": {:.2}, \"tiled_gflops\": {:.2}, \"speedup\": {:.2}}}",
            t_ref * 1e3,
            t_tiled * 1e3,
            t_packed * 1e3,
            gflops(n, p, d, t_ref),
            gflops(n, p, d, t_tiled),
            json_escape_free(speedup)
        ));
    }
    json_sections.push(format!("\"sq_dists\": [{}]", sq_rows.join(", ")));

    // ---- runtime SIMD dispatch vs forced-scalar tiles --------------------
    emit("\n== runtime SIMD dispatch (dispatched vs forced-scalar tiles) ==".into());
    let mut simd_rows: Vec<String> = Vec::new();
    for (n, p, d) in [(4096usize, 1000usize, 10usize), (4096, 1000, 100)] {
        let x = randmat(n, d, 21);
        let cm = randmat(p, d, 22);
        let packed = cm.pack_rhs();
        uspec::linalg::set_simd_override(1);
        let t_scalar = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let t_scalar_near = time_median(1, 5, || {
            std::hint::black_box(uspec::linalg::nearest_packed(&x, &packed));
        });
        let scalar_out = x.sq_dists_packed(&packed);
        uspec::linalg::set_simd_override(0);
        let t_simd = time_median(1, 5, || {
            std::hint::black_box(x.sq_dists_packed(&packed));
        });
        let t_simd_near = time_median(1, 5, || {
            std::hint::black_box(uspec::linalg::nearest_packed(&x, &packed));
        });
        // the dispatch contract, re-checked where the numbers are made
        let simd_out = x.sq_dists_packed(&packed);
        assert!(
            scalar_out.data.iter().zip(&simd_out.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "scalar and dispatched kernels diverged"
        );
        emit(format!(
            "simd n={n} p={p} d={d:3}: scalar {:7.2} ms  dispatched {:7.2} ms ({:6.2} GF/s)  sq_dists {:.2}x  nearest {:.2}x",
            t_scalar * 1e3,
            t_simd * 1e3,
            gflops(n, p, d, t_simd),
            t_scalar / t_simd,
            t_scalar_near / t_simd_near
        ));
        simd_rows.push(format!(
            "{{\"n\": {n}, \"p\": {p}, \"d\": {d}, \"scalar_ms\": {:.3}, \"dispatched_ms\": {:.3}, \"scalar_nearest_ms\": {:.3}, \"dispatched_nearest_ms\": {:.3}, \"sq_dists_speedup\": {:.2}, \"nearest_speedup\": {:.2}}}",
            t_scalar * 1e3,
            t_simd * 1e3,
            t_scalar_near * 1e3,
            t_simd_near * 1e3,
            json_escape_free(t_scalar / t_simd),
            json_escape_free(t_scalar_near / t_simd_near)
        ));
    }
    json_sections.push(format!("\"simd_dispatch\": [{}]", simd_rows.join(", ")));

    // ---- native vs PJRT pdist throughput ---------------------------------
    emit("\n== pdist throughput (native vs PJRT artifact) ==".into());
    let shapes = [(8192usize, 64usize, 2usize), (8192, 64, 16), (8192, 256, 64), (4096, 256, 784)];
    let have_artifacts = default_artifact_dir().join("manifest.json").exists();
    let mut rt = if have_artifacts { Runtime::load(default_artifact_dir()).ok() } else { None };
    for (n, c, d) in shapes {
        let x = randmat(n, d, 1);
        let cm = randmat(c, d, 2);
        let t_native = time_median(1, 3, || {
            std::hint::black_box(x.sq_dists(&cm));
        });
        emit(format!(
            "native  n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s",
            t_native * 1e3,
            gflops(n, c, d, t_native)
        ));
        if let Some(rt) = rt.as_mut() {
            let t_pjrt = time_median(1, 3, || {
                std::hint::black_box(rt.pdist(&x, &cm).unwrap());
            });
            emit(format!(
                "pjrt    n={n:5} c={c:3} d={d:3}: {:8.2} ms  {:6.2} GFLOP/s  ({:.1}x native time)",
                t_pjrt * 1e3,
                gflops(n, c, d, t_pjrt),
                t_pjrt / t_native
            ));
        }
    }

    if have_artifacts {
        emit("\n== kernel pool dispatch overhead ==".into());
        let pool = KernelPool::start(default_artifact_dir()).unwrap();
        let c = Arc::new(randmat(64, 16, 3));
        for rows in [64usize, 512, 2048] {
            let x = randmat(rows, 16, 4);
            let t = time_median(2, 5, || {
                std::hint::black_box(pool.pdist(x.clone(), c.clone()).unwrap());
            });
            emit(format!(
                "pool pdist rows={rows:5}: {:8.3} ms ({:.0} rows/ms)",
                t * 1e3,
                rows as f64 / (t * 1e3)
            ));
        }
        let backend = PjrtBackend::new(pool);
        let ds = Benchmark::Tb1m.generate(0.01, 5); // 10k points
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 1000, 20, 7).unwrap();
        let t_knr = time_median(0, 2, || {
            let index = KnrIndex::build(&reps, 50, 20, &backend).unwrap();
            std::hint::black_box(index.approx_knr(&ds.x, 5, &backend));
        });
        emit(format!(
            "approx-KNR (pjrt)   n=10000 p=1000: {:7.1} ms ({:.0} objects/s)",
            t_knr * 1e3,
            10_000.0 / t_knr
        ));
    }

    // ---- approx/exact KNR pipeline throughput (native) -------------------
    emit("\n== approx-KNR pipeline throughput (native) ==".into());
    let mut knr_rows: Vec<String> = Vec::new();
    for scale in [0.01f64, 0.05] {
        let ds = Benchmark::Tb1m.generate(scale, 5);
        let p = 1000.min(ds.n() / 2);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, p, 20, 7).unwrap();
        let index = KnrIndex::build(&reps, 50, 20, &NativeBackend).unwrap();
        let t_a = time_median(0, 3, || {
            std::hint::black_box(index.approx_knr(&ds.x, 5, &NativeBackend));
        });
        let t_e = time_median(0, 3, || {
            std::hint::black_box(index.exact_knr(&ds.x, 5, &NativeBackend));
        });
        emit(format!(
            "n={:6} p={p:4}: approx {:7.1} ms ({:9.0} obj/s)  exact {:7.1} ms  speedup {:.1}x",
            ds.n(),
            t_a * 1e3,
            ds.n() as f64 / t_a,
            t_e * 1e3,
            t_e / t_a
        ));
        knr_rows.push(format!(
            "{{\"n\": {}, \"p\": {p}, \"approx_ms\": {:.2}, \"exact_ms\": {:.2}, \"approx_objs_per_s\": {:.0}}}",
            ds.n(),
            t_a * 1e3,
            t_e * 1e3,
            ds.n() as f64 / t_a
        ));
    }
    json_sections.push(format!("\"approx_knr\": [{}]", knr_rows.join(", ")));

    emit("\n== U-SPEC end-to-end (native) ==".into());
    let mut uspec_rows: Vec<String> = Vec::new();
    for scale in [0.01f64, 0.1] {
        let ds = Benchmark::Tb1m.generate(scale, 9);
        let params =
            uspec::uspec::UspecParams { k: 2, p: 1000.min(ds.n() / 2), ..Default::default() };
        let t = time_median(0, 1, || {
            std::hint::black_box(uspec::uspec::uspec(&ds.x, &params, 3).unwrap());
        });
        emit(format!(
            "U-SPEC n={:7}: {:8.2} s  ({:9.0} objects/s)",
            ds.n(),
            t,
            ds.n() as f64 / t
        ));
        uspec_rows.push(format!(
            "{{\"n\": {}, \"seconds\": {:.3}, \"objs_per_s\": {:.0}}}",
            ds.n(),
            t,
            ds.n() as f64 / t
        ));
    }
    json_sections.push(format!("\"uspec_end_to_end\": [{}]", uspec_rows.join(", ")));

    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/micro_hotpath.txt", &out);
    eprintln!("[saved results/micro_hotpath.txt]");

    // machine-readable perf trajectory at the repo root
    let json = format!(
        "{{\n  \"harness\": \"cargo-bench\",\n  \"threads\": {},\n  \"pool_dispatches\": {},\n  {}\n}}\n",
        par::num_threads(),
        par::pool_dispatch_count(),
        json_sections.join(",\n  ")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    match std::fs::write(&path, &json) {
        Ok(()) => eprintln!("[saved {}]", path.display()),
        Err(e) => eprintln!("[failed to save {}: {e}]", path.display()),
    }
}
