//! Regenerates Tables 4–6: NMI / CA / time for all ten spectral-track
//! methods across the ten benchmark datasets. Env: USPEC_SCALE (default
//! 0.002 of paper sizes), USPEC_RUNS, USPEC_BACKEND=native|pjrt.
fn main() {
    uspec::bench::tables::bench_main(&["t4-6", "fig5"], "t4_t5_t6_spectral");
}
