//! Regenerates Table 11: quality/time vs the number of nearest
//! representatives K.
fn main() {
    uspec::bench::tables::bench_main(&["t11"], "t11_sweep_k");
}
