//! Regenerates the design-choice ablation tables (DESIGN.md §Ablations):
//! consensus function, reduced-problem eigensolver, similarity kernel,
//! and out-of-core streaming parity.
fn main() {
    uspec::bench::tables::bench_main(
        &["ablation-consensus", "ablation-eig", "ablation-kernels", "ablation-streaming"],
        "ablations",
    );
}
