//! Regenerates Tables 7–9: NMI / CA / time for the ensemble-clustering
//! methods (EAC/WCT/KCC/PTGP/ECC/SEC/LWGP/U-SENC) across the benchmarks.
fn main() {
    uspec::bench::tables::bench_main(&["t7-9"], "t7_t8_t9_ensemble");
}
