//! Regenerates Tables 13–14: hybrid vs random vs k-means representative
//! selection for U-SPEC and U-SENC (plus Fig. 1's quantization summary).
fn main() {
    uspec::bench::tables::bench_main(&["fig1", "t13-14"], "t13_t14_selection");
}
