//! Offline stub of the `xla` PJRT bindings.
//!
//! This build environment has no network and no PJRT plugin, so the real
//! bindings cannot be compiled. The stub reproduces the exact API surface
//! the `uspec::runtime` module uses and fails *at runtime* when a PJRT
//! client is requested: [`PjRtClient::cpu`] returns an [`Error`], which the
//! kernel pool surfaces to its callers, and `PjrtBackend` then falls back
//! to the native distance path. Everything downstream of client creation
//! (`compile`, `execute`, literal conversions) is therefore unreachable,
//! but still type-checks so the runtime code stays honest.
//!
//! To enable real PJRT execution, point the `xla` dependency in the root
//! `Cargo.toml` at the actual bindings crate — no source change needed.

/// Error type mirroring the real crate's (opaque string payload).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: built against the vendored xla stub (offline build); \
         the native backend handles all kernels"
            .to_string(),
    ))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// Host-side tensor value.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data_f32: Vec<f32>,
    shape: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 f32 literal from a slice.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { data_f32: values.to_vec(), shape: vec![values.len() as i64] }
    }

    /// Reshape to the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data_f32.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.data_f32.len(),
                dims
            )));
        }
        Ok(Literal { data_f32: self.data_f32.clone(), shape: dims.to_vec() })
    }

    /// First element of a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    /// Both elements of a 2-tuple literal.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    /// Read the buffer back as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    /// Dimensions of this literal.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }
}

/// Parsed HLO module (text form).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // Reading succeeds (the artifact file is real); compilation is what
        // the stub cannot do. Failing here instead keeps the error close to
        // the artifact it concerns.
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { _text: text }),
            Err(e) => Err(Error(format!("read {path}: {e}"))),
        }
    }
}

/// An XLA computation graph.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the device buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; one result row per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client — always fails in the stub; callers are expected to fall
    /// back to their native path.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}
