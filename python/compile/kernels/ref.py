"""Pure-jnp oracles for the L1 Pallas kernel and the L2 graphs.

These are the correctness ground truth: pytest asserts allclose between
each compiled path and these references over hypothesis-driven shape/value
sweeps.
"""

import jax.numpy as jnp


def pdist2_ref(x, c):
    """Reference pairwise squared distances, O(n*cn*d) direct evaluation."""
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def dist_top1_ref(x, c):
    """Nearest center per row: (labels, squared distance)."""
    d2 = pdist2_ref(x, c)
    idx = jnp.argmin(d2, axis=1)
    return idx.astype(jnp.int32), jnp.min(d2, axis=1)


def dist_topk_ref(x, c, k):
    """K nearest centers per row (ascending): (idx, d2)."""
    d2 = pdist2_ref(x, c)
    order = jnp.argsort(d2, axis=1)[:, :k]
    vals = jnp.take_along_axis(d2, order, axis=1)
    return order.astype(jnp.int32), vals


def kmeans_assign_ref(x, c, valid):
    """Nearest *valid* center per row; invalid centers are masked to +inf.

    valid: (cn,) float32 mask, 1.0 = real center, 0.0 = padding row.
    """
    d2 = pdist2_ref(x, c)
    big = jnp.float32(3.4e38)
    masked = jnp.where(valid[None, :] > 0.5, d2, big)
    idx = jnp.argmin(masked, axis=1)
    return idx.astype(jnp.int32), jnp.min(masked, axis=1)
