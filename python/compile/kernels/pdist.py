"""L1 — Pallas kernel: tiled pairwise squared Euclidean distance.

The paper's single dominant cost is the blocked evaluation of
``D2[i, j] = ||x_i - c_j||^2`` between object batches and small center sets
(rep-cluster centers, rep-cluster members, K'-neighborhoods, k-means
centers): U-SPEC's O(N * p^0.5 * d) affinity phase is a stream of such
blocks (paper §3.1.2, "batch processing manner" §3.1.4).

TPU mapping (see DESIGN.md §Hardware-Adaptation): we expand
``||x - c||^2 = ||x||^2 + ||c||^2 - 2 x·c^T`` so the dominant term is a
(B×d)·(d×C) matmul that lands on the MXU systolic array. BlockSpec tiles
the object batch along the grid (BM rows per program) while the center
block — small by construction (C ≤ a few hundred) — stays VMEM-resident
across the whole grid. The norm terms ride along as rank-1 corrections
fused into the same kernel, so the HBM traffic is exactly one pass over X.

NOTE: lowered with ``interpret=True`` everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls; on a real TPU the same kernel lowers
natively. VMEM/MXU estimates live in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of X processed per grid step. 128 matches the MXU tile edge; on CPU
# interpret mode it is simply the block length.
DEFAULT_BLOCK_M = 128


def _pdist2_kernel(x_ref, c_ref, o_ref):
    """One grid step: distances of a BM×d X-tile against all C centers.

    o[i, j] = ||x_i||^2 + ||c_j||^2 - 2 <x_i, c_j>
    """
    x = x_ref[...]  # (bm, d)
    c = c_ref[...]  # (cn, d)
    # MXU term: (bm, d) @ (d, cn). f32 accumulation (preferred_element_type)
    # keeps parity with the rust-native backend.
    g = jax.lax.dot_general(
        x,
        c,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, cn)
    o_ref[...] = jnp.maximum(xn + cn - 2.0 * g, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m",))
def pdist2(x, c, *, block_m=DEFAULT_BLOCK_M):
    """Pairwise squared distances via the Pallas kernel.

    Args:
      x: (n, d) float32 object batch; n must be a multiple of block_m
         (the AOT wrapper pads).
      c: (cn, d) float32 center block (VMEM-resident across the grid).
    Returns:
      (n, cn) float32 squared distances, clamped at 0.
    """
    n, d = x.shape
    cn = c.shape[0]
    assert n % block_m == 0, f"n={n} must be a multiple of block_m={block_m}"
    grid = (n // block_m,)
    return pl.pallas_call(
        _pdist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),  # stream X tiles
            pl.BlockSpec((cn, d), lambda i: (0, 0)),  # pin centers in VMEM
        ],
        out_specs=pl.BlockSpec((block_m, cn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cn), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, c)


def vmem_bytes(block_m: int, cn: int, d: int) -> int:
    """Static VMEM footprint estimate of one grid step (f32)."""
    x_tile = block_m * d * 4
    c_tile = cn * d * 4
    out_tile = block_m * cn * 4
    return x_tile + c_tile + out_tile


def mxu_utilization(block_m: int, cn: int, d: int) -> float:
    """Fraction of 128×128×8-lane MXU work that is useful (non-padding)."""

    def ceil_to(v, q):
        return -(-v // q) * q

    useful = block_m * cn * d
    padded = ceil_to(block_m, 128) * ceil_to(cn, 128) * ceil_to(d, 8)
    return useful / padded
