"""L2 — JAX compute graphs served to the rust coordinator.

Each graph wraps the L1 Pallas kernel (`kernels.pdist.pdist2`) with the
fixed-shape pre/post-processing the coordinator's hot loops need. All
shapes are static — the AOT step compiles one artifact per (B, C, d)
variant and the rust side pads batches to fit (padding rows of X are
ignored by the caller; padding rows of C are masked by `valid`).

Graphs:
  * ``pdist``         — raw squared-distance block (B×C). The workhorse of
                        the approximate-KNR three-step search.
  * ``dist_top1``     — fused nearest-center: labels + min distance, with a
                        validity mask over centers (k-means assign / KNR
                        step 1 & 2).
  * ``dist_topk``     — fused top-K nearest centers (KNR step 3).

Every graph returns a tuple (lowered with return_tuple=True) — the rust
loader unwraps with ``to_tuple1``/``to_tupleN``.
"""

import jax
import jax.numpy as jnp

from .kernels.pdist import pdist2


def pdist_graph(x, c):
    """(B, d) × (C, d) → ((B, C) squared distances,)."""
    return (pdist2(x, c),)


def dist_top1_graph(x, c, valid):
    """Nearest valid center: ((B,) int32 labels, (B,) f32 min-distance)."""
    d2 = pdist2(x, c)
    big = jnp.float32(3.4e38)
    masked = jnp.where(valid[None, :] > 0.5, d2, big)
    idx = jnp.argmin(masked, axis=1).astype(jnp.int32)
    dist = jnp.min(masked, axis=1)
    return (idx, dist)


def dist_topk_graph(x, c, valid, *, k):
    """K nearest valid centers: ((B, k) int32 idx, (B, k) f32 d2)."""
    d2 = pdist2(x, c)
    big = jnp.float32(3.4e38)
    masked = jnp.where(valid[None, :] > 0.5, d2, big)
    neg_vals, idx = jax.lax.top_k(-masked, k)
    return (idx.astype(jnp.int32), -neg_vals)


def lower_variant(name, b, c, d, k=None):
    """Lower one graph variant to a jax Lowered object.

    Returns (lowered, arg_spec_summary).
    """
    xs = jax.ShapeDtypeStruct((b, d), jnp.float32)
    cs = jax.ShapeDtypeStruct((c, d), jnp.float32)
    vs = jax.ShapeDtypeStruct((c,), jnp.float32)
    if name == "pdist":
        fn = jax.jit(pdist_graph)
        return fn.lower(xs, cs), ["x", "c"]
    if name == "dist_top1":
        fn = jax.jit(dist_top1_graph)
        return fn.lower(xs, cs, vs), ["x", "c", "valid"]
    if name == "dist_topk":
        assert k is not None and k >= 1
        fn = jax.jit(lambda x, cc, v: dist_topk_graph(x, cc, v, k=k))
        return fn.lower(xs, cs, vs), ["x", "c", "valid"]
    raise ValueError(f"unknown graph {name}")
