"""AOT compile path: lower every L2 graph variant to HLO *text* under
``artifacts/`` plus a ``manifest.json`` the rust runtime loads at startup.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Variant grid (see DESIGN.md):
  * ``pdist``      B=2048, C in {64, 256}, d in {2, 16, 64, 256, 784}
  * ``dist_top1``  B=2048, C=64, same d grid
  * ``dist_topk``  B=2048, C=64, K=5, same d grid
The rust side pads (B rows, C rows via the validity mask, d columns with
zeros — zero-padding the feature dimension leaves distances unchanged) and
picks the smallest variant that fits.

Usage: python -m compile.aot --out ../artifacts
Python runs ONLY here; the rust binary never shells out to it.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

BATCH = 2048
DIMS = [2, 16, 64, 256, 784]
PDIST_CENTERS = [64, 256]
TOPK_K = 5


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    for d in DIMS:
        for c in PDIST_CENTERS:
            yield ("pdist", BATCH, c, d, None)
        yield ("dist_top1", BATCH, 64, d, None)
        yield ("dist_topk", BATCH, 64, d, TOPK_K)


def variant_name(graph, b, c, d, k):
    suffix = f"_k{k}" if k is not None else ""
    return f"{graph}_b{b}_c{c}_d{d}{suffix}"


def input_fingerprint() -> str:
    """Hash of the compile-path sources — the Makefile-level no-op check."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    h.update(jax.__version__.encode())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    fp = input_fingerprint()

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and all(
                os.path.exists(os.path.join(args.out, a["file"])) for a in old["artifacts"]
            ):
                print(f"artifacts fresh (fingerprint {fp}); nothing to do")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass

    arts = []
    for graph, b, c, d, k in variants():
        name = variant_name(graph, b, c, d, k)
        lowered, inputs = model.lower_variant(graph, b, c, d, k)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        n_outputs = {"pdist": 1, "dist_top1": 2, "dist_topk": 2}[graph]
        arts.append(
            {
                "name": name,
                "graph": graph,
                "file": fname,
                "b": b,
                "c": c,
                "d": d,
                "k": k,
                "inputs": inputs,
                "outputs": n_outputs,
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    with open(manifest_path, "w") as f:
        json.dump(
            {"fingerprint": fp, "batch": BATCH, "artifacts": arts},
            f,
            indent=1,
        )
    print(f"wrote {len(arts)} artifacts + manifest to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
