#!/usr/bin/env python3
"""Python mirror of `cargo bench --bench micro_hotpath`.

This container ships no Rust toolchain, so this harness measures the same
two hot-path phenomena the Rust changes target, at the same shapes, and
emits `BENCH_hotpath.json` at the repo root in the same schema:

* ``pool_dispatch`` — per-region dispatch cost of spawning fresh OS
  threads per parallel region (the old `util/par.rs` behavior) vs
  dispatching onto a persistent pool of already-running workers (the new
  behavior). Thread creation cost is an OS property, not a language one,
  so the before/after ratio transfers.
* ``sq_dists`` — pairwise-squared-distance throughput at the paper's
  KNR batch shapes (N=4096 batch, p=1000 representatives, d ∈ {10, 100}):
  a row-at-a-time formulation with per-row temporaries (the old scalar
  kernel's memory behavior) vs one blocked pass with preallocated
  outputs and a reused RHS (the new packed kernel's memory behavior).
* ``simd_dispatch`` — the runtime-dispatched vector tiles vs the forced
  scalar tiles (`USPEC_SIMD=0`). Proxy legs: a non-vectorized einsum
  contraction (NumPy's own C loop, no BLAS) stands in for the scalar
  reference tile, a row-blocked BLAS gemm with the distance epilogue
  fused per cache-resident block stands in for the dispatched
  vector tile + shared epilogue. The Rust kernels are bit-identical
  across dispatch levels; these legs only mirror the *throughput* gap.
* ``eig`` — the reduced p×p transfer-cut eigensolve hot loop (fixed-shape
  Chebyshev-filtered subspace iteration: DEG=8 gemm applies plus a
  Rayleigh–Ritz projection per outer step, f64 throughout). Proxy legs:
  the reference leg contracts every block product with a non-BLAS einsum
  and fresh temporaries (the old branchy `DMat::matmul` + per-iteration
  allocation), the packed leg runs `np.dot` into preallocated buffers
  (the packed f64 tiles + `EigScratch` reuse). Orthonormalization is
  `np.linalg.qr` in both legs. This is a throughput-only proxy — the
  scalar-vs-dispatched *bit-identity* contract is asserted in the Rust
  bench (and `reduced_eig_bit_identical_across_threads_and_simd`) where
  the numbers are made.
* ``argmin_k`` — per-row top-K selection with a fresh f64 copy + full
  argsort per row (old `argmin_k` usage) vs `argpartition` into
  preallocated f32 scratch (new `argmin_k_into`).
* ``chunk_sweep`` — overhead of the staged pipeline's chunked KNR pass
  (read chunk → distance block → per-row top-K, one reused chunk buffer)
  relative to one monolithic N-row pass, across chunk sizes. The engine
  is chunk-size *invariant* in results; this tracks what the chunking
  costs in time so the default chunk stays in the flat region.
* ``shard_sweep`` — the sharded-DataSource walk: an out-of-core KNR pass
  over an on-disk file, alternating read↔compute in one sequential
  walker vs (a) the old fixed plan — one walker + one prefetch reader
  per shard (``sharded_ms``, degrades as shards grow past the core
  budget) — and (b) the adaptive walk plan (``adaptive_ms``): walker
  count and prefetch depth from `pipeline::shard::plan_walk`, walkers
  claiming shards off a shared queue. Mirrors
  `pipeline::shard::for_each_chunk_sharded`.
* ``net`` — the remote-I/O fast path: USPEC/2 wire-compression ratio
  (byte-shuffle + RLE, the exact `net::codec` token grammar) on
  sparse-clustered vs incompressible rows, and a multi-pass chunk walk with
  the decoded-chunk LRU on vs off. Throughput-only proxy — the
  lossless/bit-identity contracts live in the Rust tests.

Pass ``--smoke`` for a fast CI sanity run (smaller shapes, fewer
iterations, same schema). ``--smoke`` is also the CI bench-regression
gate: the fresh run is compared per-section against the committed
``BENCH_hotpath.json`` (geometric mean of the higher-is-better
``speedup``/``ratio`` fields) and the process exits nonzero when any
section lands below 75% of its committed aggregate. Incomparable
baselines (different mode/harness, metric-less sections) are skipped
loudly, never failed.

When a Rust toolchain is available, `cargo bench --bench micro_hotpath`
overwrites this file with natively measured numbers (``harness`` tells
you which produced it).
"""

import collections
import json
import os
import queue
import sys
import tempfile
import time
import concurrent.futures
import threading

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NT = os.cpu_count() or 4


def time_median(warmup, iters, fn):
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _timed(fn):
    """One timed call. The walk benches interleave these round-robin and
    keep per-config minima: every iteration performs identical work, so
    the minimum is the least-noise estimate, and interleaving spreads
    slow drift (page cache, CPU contention) over every config equally."""
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------- dispatch
def spawn_region(n_tasks, work):
    """Old model: spawn + join fresh OS threads for one parallel region."""
    nt = min(NT, n_tasks)
    chunk = (n_tasks + nt - 1) // nt
    out = [None] * n_tasks

    def run(base):
        for i in range(base, min(base + chunk, n_tasks)):
            out[i] = work(i)

    threads = [threading.Thread(target=run, args=(t * chunk,)) for t in range(nt)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out


def bench_dispatch(smoke=False):
    rows = []
    pool = concurrent.futures.ThreadPoolExecutor(max_workers=NT)
    work = lambda i: i * 3  # noqa: E731 — trivial task isolates dispatch cost

    def pool_region(n_tasks):
        nt = min(NT, n_tasks)
        chunk = (n_tasks + nt - 1) // nt
        futs = [
            pool.submit(lambda base: [work(i) for i in range(base, min(base + chunk, n_tasks))], t * chunk)
            for t in range(nt)
        ]
        return [f.result() for f in futs]

    # warm the pool workers
    pool_region(64)
    for n in (16, 64) if smoke else (16, 64, 256):
        reps = 10 if smoke else 30
        t_spawn = time_median(2, 5, lambda: [spawn_region(n, work) for _ in range(reps)]) / reps
        t_pool = time_median(2, 5, lambda: [pool_region(n) for _ in range(reps)]) / reps
        rows.append(
            {
                "n": n,
                "spawn_us": round(t_spawn * 1e6, 3),
                "pool_us": round(t_pool * 1e6, 3),
                "speedup": round(t_spawn / t_pool, 2),
            }
        )
        print(
            f"dispatch n={n:4d}: spawn {t_spawn * 1e6:8.1f} µs  pool {t_pool * 1e6:8.1f} µs  "
            f"speedup {t_spawn / t_pool:.1f}x"
        )
    pool.shutdown()
    return rows


# ---------------------------------------------------------------- sq_dists
def sq_dists_rowwise(x, c):
    """Old memory behavior: per-row temporaries, two passes over the row."""
    out = np.empty((x.shape[0], c.shape[0]), dtype=np.float32)
    cn = (c * c).sum(axis=1)
    for i in range(x.shape[0]):
        g = c @ x[i]  # fresh temporary per row
        xn = float(x[i] @ x[i])
        out[i] = np.maximum(xn + cn - 2.0 * g, 0.0)
    return out


def sq_dists_blocked(x, c_t, cn, out, tmp):
    """New memory behavior: one blocked gemm pass, preallocated buffers,
    reused (pre-transposed) RHS."""
    np.dot(x, c_t, out=tmp)
    xn = np.einsum("ij,ij->i", x, x)
    np.multiply(tmp, -2.0, out=out)
    out += xn[:, None]
    out += cn[None, :]
    np.maximum(out, 0.0, out=out)
    return out


def bench_sq_dists(smoke=False):
    rows = []
    rng = np.random.default_rng(11)
    shapes = ((1024, 500, 10),) if smoke else ((4096, 1000, 10), (4096, 1000, 100))
    for n, p, d in shapes:
        x = rng.standard_normal((n, d)).astype(np.float32)
        c = rng.standard_normal((p, d)).astype(np.float32)
        c_t = np.ascontiguousarray(c.T)
        cn = (c * c).sum(axis=1)
        out = np.empty((n, p), dtype=np.float32)
        tmp = np.empty((n, p), dtype=np.float32)
        t_ref = time_median(1, 3, lambda: sq_dists_rowwise(x, c))
        t_tiled = time_median(1, 5, lambda: sq_dists_blocked(x, c_t, cn, out, tmp))
        gf = lambda t: 2.0 * n * p * d / t / 1e9  # noqa: E731
        rows.append(
            {
                "n": n,
                "p": p,
                "d": d,
                "ref_ms": round(t_ref * 1e3, 3),
                "tiled_ms": round(t_tiled * 1e3, 3),
                "packed_reuse_ms": round(t_tiled * 1e3, 3),
                "ref_gflops": round(gf(t_ref), 2),
                "tiled_gflops": round(gf(t_tiled), 2),
                "speedup": round(t_ref / t_tiled, 2),
            }
        )
        print(
            f"sq_dists n={n} p={p} d={d:3d}: ref {t_ref * 1e3:8.2f} ms ({gf(t_ref):6.2f} GF/s)  "
            f"blocked {t_tiled * 1e3:8.2f} ms ({gf(t_tiled):6.2f} GF/s)  "
            f"speedup {t_ref / t_tiled:.1f}x"
        )
    return rows


# ----------------------------------------------------------- simd dispatch
def bench_simd_dispatch(smoke=False):
    """Runtime SIMD dispatch vs forced-scalar tiles (see module docstring
    for the proxy-leg mapping). The scalar leg contracts with einsum
    (optimize=False keeps NumPy's own non-BLAS C loop — the scalar tile's
    instruction mix); the dispatched leg runs the gemm row-block by
    row-block and fuses the distance epilogue (and the argmin for the
    nearest leg) while the block is cache-resident, which is what the
    vector tiles + shared scalar epilogue do per register tile."""
    rows = []
    rng = np.random.default_rng(21)
    block = 256  # rows per cache-resident gemm block
    shapes = ((1024, 500, 10),) if smoke else ((4096, 1000, 10), (4096, 1000, 100))
    for n, p, d in shapes:
        x = rng.standard_normal((n, d)).astype(np.float32)
        c = rng.standard_normal((p, d)).astype(np.float32)
        c_t = np.ascontiguousarray(c.T)
        cn = (c * c).sum(axis=1)

        def scalar_dists():
            g = np.einsum("ij,kj->ik", x, c, optimize=False)
            xn = np.einsum("ij,ij->i", x, x)
            return np.maximum(xn[:, None] + cn[None, :] - 2.0 * g, 0.0)

        def scalar_nearest():
            return np.argmin(scalar_dists(), axis=1)

        out = np.empty((block, p), dtype=np.float32)
        tmp = np.empty_like(out)
        full = np.empty((n, p), dtype=np.float32)
        labels = np.empty(n, dtype=np.int64)

        def dispatched_dists():
            for lo in range(0, n, block):
                hi = min(lo + block, n)
                o, t = out[: hi - lo], tmp[: hi - lo]
                sq_dists_blocked(x[lo:hi], c_t, cn, o, t)
                full[lo:hi] = o
            return full

        def dispatched_nearest():
            for lo in range(0, n, block):
                hi = min(lo + block, n)
                o, t = out[: hi - lo], tmp[: hi - lo]
                sq_dists_blocked(x[lo:hi], c_t, cn, o, t)
                labels[lo:hi] = np.argmin(o, axis=1)  # fused, block in cache
            return labels

        iters = 3 if smoke else 5
        t_scalar = time_median(1, iters, scalar_dists)
        t_disp = time_median(1, iters, dispatched_dists)
        t_scalar_near = time_median(1, iters, scalar_nearest)
        t_disp_near = time_median(1, iters, dispatched_nearest)
        gf = lambda t: 2.0 * n * p * d / t / 1e9  # noqa: E731
        rows.append(
            {
                "n": n,
                "p": p,
                "d": d,
                "scalar_ms": round(t_scalar * 1e3, 3),
                "dispatched_ms": round(t_disp * 1e3, 3),
                "scalar_nearest_ms": round(t_scalar_near * 1e3, 3),
                "dispatched_nearest_ms": round(t_disp_near * 1e3, 3),
                "dispatched_gflops": round(gf(t_disp), 2),
                "sq_dists_speedup": round(t_scalar / t_disp, 2),
                "nearest_speedup": round(t_scalar_near / t_disp_near, 2),
            }
        )
        print(
            f"simd n={n} p={p} d={d:3d}: scalar {t_scalar * 1e3:8.2f} ms  "
            f"dispatched {t_disp * 1e3:8.2f} ms ({gf(t_disp):6.2f} GF/s)  "
            f"sq_dists {t_scalar / t_disp:.1f}x  nearest {t_scalar_near / t_disp_near:.1f}x"
        )
    return rows


# ------------------------------------------------------------- reduced eig
def bench_eig(smoke=False):
    """Reduced p×p eigensolve hot loop (see module docstring for the
    proxy-leg mapping). Both legs run the identical fixed-shape iteration
    — same start block, same filter bound, same step count — so the only
    difference is how the block products are contracted and whether the
    buffers are reused; the top-k Ritz values must agree to rounding."""
    rows = []
    rng = np.random.default_rng(31)
    DEG, NSTEP = 8, 3
    shapes = ((400, 10),) if smoke else ((400, 10), (1200, 10))
    for p, k in shapes:
        q = k + 8
        # Gaussian affinity over a 2-D three-cluster mixture — the same
        # near-block-diagonal spectrum the Rust bench solves.
        centers = np.array([[0.0, 0.0], [6.0, 0.0], [0.0, 6.0]])
        pts = centers[np.arange(p) % 3] + rng.standard_normal((p, 2))
        sq = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        e_r = np.exp(-sq / 4.0)
        dis = 1.0 / np.sqrt(e_r.sum(axis=1))
        s = e_r * dis[:, None] * dis[None, :]
        x0 = rng.standard_normal((p, q))
        inv = 2.0 / 0.5  # fixed filter bound: identical work per leg

        def ref_solve():
            x, _ = np.linalg.qr(x0)
            vals = None
            for _ in range(NSTEP):
                z_prev = x.copy()
                z = np.einsum("ij,jk->ik", s, x, optimize=False) * inv - x
                for _ in range(2, DEG + 1):
                    z_next = np.einsum("ij,jk->ik", s, z, optimize=False) * inv - z
                    z_next = 2.0 * z_next - z_prev
                    z_prev, z = z, z_next
                x, _ = np.linalg.qr(z)
                sx = np.einsum("ij,jk->ik", s, x, optimize=False)
                h = np.einsum("ji,jk->ik", x, sx, optimize=False)
                hvals, hvecs = np.linalg.eigh(0.5 * (h + h.T))
                vals = hvals[::-1][:k]
                x = np.einsum("ij,jk->ik", x, hvecs, optimize=False)
            return vals

        cheb = [np.empty((p, q)) for _ in range(3)]
        sx_buf = np.empty((p, q))
        h_buf = np.empty((q, q))
        rot_buf = np.empty((p, q))

        def packed_solve():
            x, _ = np.linalg.qr(x0)
            vals = None
            for _ in range(NSTEP):
                c0, c1, c2 = cheb
                np.copyto(c0, x)
                np.dot(s, x, out=c1)
                c1 *= inv
                c1 -= x
                for _ in range(2, DEG + 1):
                    np.dot(s, c1, out=c2)
                    c2 *= inv
                    c2 -= c1
                    c2 *= 2.0
                    c2 -= c0
                    c0, c1, c2 = c1, c2, c0
                x, _ = np.linalg.qr(c1)
                np.dot(s, x, out=sx_buf)
                np.dot(x.T, sx_buf, out=h_buf)
                hvals, hvecs = np.linalg.eigh(0.5 * (h_buf + h_buf.T))
                vals = hvals[::-1][:k]
                np.dot(x, hvecs, out=rot_buf)
                x = rot_buf
            return vals

        # same math, different contraction order: Ritz values agree
        assert np.allclose(ref_solve(), packed_solve(), atol=1e-9)
        iters = 2 if smoke else 3
        t_ref = time_median(0, iters, ref_solve)
        t_packed = time_median(1, iters, packed_solve)
        rows.append(
            {
                "p": p,
                "k": k,
                "ref_ms": round(t_ref * 1e3, 3),
                "dispatched_ms": round(t_packed * 1e3, 3),
                "speedup": round(t_ref / t_packed, 2),
            }
        )
        print(
            f"eig p={p:4d} k={k}: einsum+alloc {t_ref * 1e3:8.2f} ms  "
            f"packed+scratch {t_packed * 1e3:8.2f} ms  speedup {t_ref / t_packed:.1f}x"
        )
    return rows


# ---------------------------------------------------------------- argmin_k
def bench_argmin(smoke=False):
    rows = []
    rng = np.random.default_rng(7)
    n_rows, p, k = (500 if smoke else 2000), 1000, 5
    d2 = rng.random((n_rows, p), dtype=np.float32)

    def old_path():
        acc = 0
        for i in range(n_rows):
            row = d2[i].astype(np.float64)  # fresh f64 copy per row (old)
            acc += int(np.argsort(row, kind="stable")[:k][0])
        return acc

    idx_scratch = np.empty(p, dtype=np.int64)

    def new_path():
        acc = 0
        for i in range(n_rows):
            row = d2[i]
            top = np.argpartition(row, k - 1)[:k]
            top = top[np.argsort(row[top], kind="stable")]
            idx_scratch[:k] = top
            acc += int(idx_scratch[0])
        return acc

    t_old = time_median(1, 3, old_path)
    t_new = time_median(1, 3, new_path)
    rows.append(
        {
            "rows": n_rows,
            "p": p,
            "k": k,
            "old_us_per_row": round(t_old / n_rows * 1e6, 3),
            "new_us_per_row": round(t_new / n_rows * 1e6, 3),
            "speedup": round(t_old / t_new, 2),
        }
    )
    print(
        f"argmin_k rows={n_rows} p={p} k={k}: full-sort+copy {t_old / n_rows * 1e6:6.2f} µs/row  "
        f"partition+scratch {t_new / n_rows * 1e6:6.2f} µs/row  speedup {t_old / t_new:.1f}x"
    )
    return rows


# ------------------------------------------------------------- chunk sweep
def bench_chunk_sweep(smoke=False):
    """Chunked pipeline pass-2 (sq_dists + per-row top-K per chunk, one
    reused chunk buffer) vs the monolithic full-N pass, at the paper's
    KNR shape (p=1000 representatives, K=5)."""
    rows = []
    rng = np.random.default_rng(23)
    n, p, d, k = (16_384 if smoke else 65_536), 1000, 10, 5
    x = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((p, d)).astype(np.float32)
    c_t = np.ascontiguousarray(c.T)
    cn = (c * c).sum(axis=1)

    def chunked_pass(chunk):
        out = np.empty((chunk, p), dtype=np.float32)
        tmp = np.empty((chunk, p), dtype=np.float32)
        acc = 0
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            xb = x[lo:hi]
            o, t = out[: hi - lo], tmp[: hi - lo]
            sq_dists_blocked(xb, c_t, cn, o, t)
            top = np.argpartition(o, k - 1, axis=1)[:, :k]
            acc += int(top[0, 0])
        return acc

    t_full = time_median(1, 3, lambda: chunked_pass(n))
    for chunk in (1024, 4096, n) if smoke else (1024, 4096, 8192, 32768, n):
        t = time_median(1, 3, lambda: chunked_pass(chunk))
        rows.append(
            {
                "n": n,
                "p": p,
                "d": d,
                "k": k,
                "chunk": chunk,
                "ms": round(t * 1e3, 3),
                "overhead_vs_full": round(t / t_full, 3),
            }
        )
        print(
            f"chunk_sweep n={n} chunk={chunk:6d}: {t * 1e3:8.2f} ms  "
            f"overhead vs monolithic {t / t_full:.2f}x"
        )
    return rows


# -------------------------------------------------------------------- net
# Mirror of `net::codec` (USPEC/2 wire compression): byte-shuffle the 4
# bytes of every f32 into 4 planes, then byte-RLE. Token grammar matches
# the Rust encoder exactly: control c < 0x80 = literal run of c+1 bytes
# (1..=128); c >= 0x80 = the next byte repeated (c-0x80)+3 times
# (3..=130); runs shorter than 3 fold into literals.
NET_MIN_RUN, NET_MAX_RUN, NET_MAX_LIT = 3, 130, 128


def net_shuffle(raw):
    """f32 bytes -> 4 concatenated byte planes (all byte-0s, byte-1s, …)."""
    return np.frombuffer(raw, dtype=np.uint8).reshape(-1, 4).T.tobytes()


def net_unshuffle(planes):
    return np.frombuffer(planes, dtype=np.uint8).reshape(4, -1).T.tobytes()


def net_rle_encode(b):
    out = bytearray()
    n, i = len(b), 0
    while i < n:
        run = 1
        while i + run < n and b[i + run] == b[i] and run < NET_MAX_RUN:
            run += 1
        if run >= NET_MIN_RUN:
            out.append(0x80 + run - NET_MIN_RUN)
            out.append(b[i])
            i += run
            continue
        start = i
        while i < n and i - start < NET_MAX_LIT:
            if i + NET_MIN_RUN <= n and b[i] == b[i + 1] == b[i + 2]:
                break
            i += 1
        out.append(i - start - 1)
        out += b[start:i]
    return bytes(out)


def net_rle_decode(s):
    out = bytearray()
    i, n = 0, len(s)
    while i < n:
        c = s[i]
        i += 1
        if c < 0x80:
            lit = c + 1
            out += s[i:i + lit]
            i += lit
        else:
            out += bytes([s[i]]) * ((c - 0x80) + NET_MIN_RUN)
            i += 1
    return bytes(out)


def net_compress(raw):
    """Rust `codec::compress`: length prefix + RLE(shuffled planes);
    None when not strictly smaller (the server then sends plain rows)."""
    enc = len(raw).to_bytes(4, "little") + net_rle_encode(net_shuffle(raw))
    return enc if len(enc) < len(raw) else None


def bench_net(smoke=False):
    """Remote-I/O fast path, python mirror (throughput-only: the Rust
    suite asserts the bit-identity and never-touches-the-socket
    contracts where the bytes are made — `net::codec` tests and the
    `sharded_equivalence` remote legs).

    * ``codec`` — wire bytes moved with USPEC/2 compression on sparse
      clustered f32 rows (a few active dims per row, exact zeros
      elsewhere — the zero stretches become long byte runs after the
      shuffle) vs dense random rows (no byte runs: the codec declines
      and the server falls back to plain frames — ratio pinned at 1.0,
      never worse).
    * ``multi_pass_cache`` — an m-pass chunk walk (U-SENC re-reads one
      chunk grid m times) with the decoded-chunk LRU on vs off; a hit
      returns the resident array and skips the read+decode entirely,
      mirroring `RemoteSource`'s cache-hit-never-touches-the-socket
      contract.
    """
    rng = np.random.default_rng(41)
    codec_rows = []
    n_rows, d, active = (1024, 16, 2) if smoke else (4096, 16, 2)
    # sparse clustered rows (MNIST-style): each row has `active` dims
    # near its cluster's center and exact 0.0 elsewhere — the zero
    # stretches become long byte runs after the shuffle. Dense rows with
    # float-to-float byte variety produce no runs; the codec declines
    # and the server sends plain frames (the `random` leg).
    sparse = np.zeros((n_rows, d), dtype=np.float32)
    centers = rng.standard_normal((2, active)).astype(np.float32) * 2.0
    jit = (rng.random((n_rows, active), dtype=np.float32) - 0.5) * 1e-3
    for i in range(n_rows):
        off = (i % 2) * active  # disjoint active dims per center
        sparse[i, off:off + active] = centers[i % 2] + jit[i]
    random_rows = rng.standard_normal((n_rows, d)).astype(np.float32)
    for name, mat in (("sparse-clustered", sparse), ("random", random_rows)):
        raw = mat.tobytes()
        t0 = time.perf_counter()
        comp = net_compress(raw)
        t_enc = time.perf_counter() - t0
        if comp is not None:
            # bit-exact roundtrip, NaN-payload-safe by construction
            assert net_unshuffle(net_rle_decode(comp[4:])) == raw
            wire = len(comp)
        else:
            wire = len(raw)  # plain-frame fallback: never a regression
        ratio = len(raw) / wire
        codec_rows.append(
            {
                "data": name,
                "rows": n_rows,
                "d": d,
                "raw_bytes": len(raw),
                "wire_bytes": wire,
                "ratio": round(ratio, 2),
                "fallback_plain": comp is None,
                "encode_mb_s": round(len(raw) / 1e6 / t_enc, 2),
            }
        )
        print(
            f"net codec {name:9s}: {len(raw)} -> {wire} bytes  "
            f"ratio {ratio:.2f}x{'  (plain fallback)' if comp is None else ''}"
        )
    assert codec_rows[0]["ratio"] >= 2.0, "sparse clustered rows must shrink >= 2x"
    assert codec_rows[1]["ratio"] >= 1.0, "fallback must never expand the wire"

    # multi-pass chunk walk, cache on vs off
    n, d, chunk, passes = (16_384, 8, 2048, 5) if smoke else (65_536, 16, 4096, 6)
    path = os.path.join(tempfile.gettempdir(), f"uspec_net_cache_{os.getpid()}.bin")
    rng.standard_normal((n, d)).astype(np.float32).tofile(path)

    def fetch(lo, hi):
        cnt = (hi - lo) * d
        return np.fromfile(path, dtype=np.float32, count=cnt, offset=lo * d * 4).reshape(-1, d)

    grid = [(lo, min(lo + chunk, n)) for lo in range(0, n, chunk)]

    def walk_uncached():
        acc = 0
        for _ in range(passes):
            for lo, hi in grid:
                acc += fetch(lo, hi).shape[0]
        return acc

    def walk_cached():
        cache = {}
        acc = 0
        for _ in range(passes):
            for key in grid:
                buf = cache.get(key)
                if buf is None:
                    buf = fetch(*key)
                    cache[key] = buf  # budget = one full grid, like the tests
                acc += buf.shape[0]
        return acc

    try:
        assert walk_uncached() == walk_cached() == passes * n
        iters = 2 if smoke else 4
        t_off = min(_timed(walk_uncached) for _ in range(iters))
        t_on = min(_timed(walk_cached) for _ in range(iters))
    finally:
        os.remove(path)
    assert t_on < t_off, "cache-on multi-pass walk must beat re-fetching"
    cache_rows = [
        {
            "n": n,
            "d": d,
            "chunk": chunk,
            "passes": passes,
            "uncached_ms": round(t_off * 1e3, 3),
            "cached_ms": round(t_on * 1e3, 3),
            "speedup": round(t_off / t_on, 2),
        }
    ]
    print(
        f"net cache n={n} passes={passes}: uncached {t_off * 1e3:8.2f} ms  "
        f"cached {t_on * 1e3:8.2f} ms  speedup {t_off / t_on:.1f}x"
    )
    return {
        "note": (
            "throughput-only python mirror; bit-identity and the "
            "cache-hit-never-touches-the-socket contract are asserted in "
            "the Rust net::codec tests and sharded_equivalence remote legs"
        ),
        "codec": codec_rows,
        "multi_pass_cache": cache_rows,
    }


# ------------------------------------------------------------- shard sweep
def plan_walk(shards, budget):
    """Mirror of `pipeline::shard::plan_walk` for the Parallel/Auto
    profile: walkers scale toward half the thread budget (the walkers'
    chunk compute dispatches into the worker pool, so walkers ≈ budget
    would oversubscribe the cores 2×), prefetch depth 2."""
    return max(min(shards, max(budget // 2, 1)), 1), 2


def bench_shard_sweep(smoke=False):
    """Sharded out-of-core pass (mirror of
    `pipeline::shard::for_each_chunk_sharded`): an on-disk KNR pass
    (read chunk → sq_dists → per-row top-K) walked (a) sequentially,
    alternating read and compute; (b) with the old fixed plan — one
    walker + one prefetch reader per shard; (c) with the adaptive walk
    plan — `plan_walk` walkers claiming shards off a shared queue, each
    prefetching `depth` chunks ahead. Shards/walkers/prefetch are
    operational only — every walk visits every row once."""
    rows = []
    rng = np.random.default_rng(31)
    n, p, d, k, chunk = (32_768 if smoke else 131_072), 1000, 16, 5, 4096
    c = rng.standard_normal((p, d)).astype(np.float32)
    c_t = np.ascontiguousarray(c.T)
    cn = (c * c).sum(axis=1)
    path = os.path.join(tempfile.gettempdir(), f"uspec_shard_sweep_{os.getpid()}.bin")
    rng.standard_normal((n, d)).astype(np.float32).tofile(path)

    def read_chunk(lo, hi):
        cnt = (hi - lo) * d
        buf = np.fromfile(path, dtype=np.float32, count=cnt, offset=lo * d * 4)
        return buf.reshape(hi - lo, d)

    def compute(xb):
        out = np.empty((xb.shape[0], p), dtype=np.float32)
        tmp = np.empty_like(out)
        sq_dists_blocked(xb, c_t, cn, out, tmp)
        np.argpartition(out, k - 1, axis=1)  # per-row top-K (the KNR work)
        # Walkers accumulate the row count: an exact, partition-independent
        # coverage check (kernel outputs can differ in rounding across
        # chunk shapes, so they are workload, not checksum).
        return xb.shape[0]

    def sequential():
        acc = 0
        for lo in range(0, n, chunk):
            acc += compute(read_chunk(lo, min(lo + chunk, n)))
        return acc

    def sharded(shards):
        bounds = [(i * n) // shards for i in range(shards + 1)]
        readers = concurrent.futures.ThreadPoolExecutor(max_workers=shards)
        workers = concurrent.futures.ThreadPoolExecutor(max_workers=shards)

        def walk(lo, hi):
            if lo >= hi:
                return 0
            fut = readers.submit(read_chunk, lo, min(lo + chunk, hi))
            acc, t = 0, lo
            while t < hi:
                nxt = min(t + chunk, hi)
                buf = fut.result()
                if nxt < hi:  # prefetch chunk i+1 while computing on chunk i
                    fut = readers.submit(read_chunk, nxt, min(nxt + chunk, hi))
                acc += compute(buf)
                t = nxt
            return acc

        futs = [workers.submit(walk, bounds[i], bounds[i + 1]) for i in range(shards)]
        acc = sum(f.result() for f in futs)
        readers.shutdown()
        workers.shutdown()
        return acc

    def walked(shards, walkers, depth):
        """The adaptive walk: `walkers` threads claim shards off a queue
        (the engine's atomic-cursor idiom), each keeping up to `depth`
        chunk reads in flight while computing."""
        bounds = [(i * n) // shards for i in range(shards + 1)]
        todo = queue.SimpleQueue()
        for i in range(shards):
            todo.put(i)
        readers = concurrent.futures.ThreadPoolExecutor(max_workers=walkers)
        totals = [0] * walkers

        def walker(w):
            acc = 0
            while True:
                try:
                    i = todo.get_nowait()
                except queue.Empty:
                    break
                lo, hi = bounds[i], bounds[i + 1]
                if lo >= hi:
                    continue
                pending = collections.deque()
                nxt = lo
                while nxt < hi and len(pending) < depth:
                    end = min(nxt + chunk, hi)
                    pending.append(readers.submit(read_chunk, nxt, end))
                    nxt = end
                while pending:
                    buf = pending.popleft().result()
                    while nxt < hi and len(pending) < depth:
                        end = min(nxt + chunk, hi)
                        pending.append(readers.submit(read_chunk, nxt, end))
                        nxt = end
                    acc += compute(buf)
            totals[w] = acc

        threads = [threading.Thread(target=walker, args=(w,)) for w in range(walkers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        readers.shutdown()
        return sum(totals)

    try:
        assert sequential() == n, "sequential walk must cover every row"
        iters = 2 if smoke else 5
        sweep = (1, 2) if smoke else (1, 2, 4, 8)
        plans = {s: plan_walk(s, NT) for s in sweep}

        def chunk_stream(shards):
            """The (lo, hi) chunk sequence a walk over `shards` shards
            reads, in claim order."""
            bounds = [(i * n) // shards for i in range(shards + 1)]
            out = []
            for i in range(shards):
                t = bounds[i]
                while t < bounds[i + 1]:
                    nxt = min(t + chunk, bounds[i + 1])
                    out.append((t, nxt))
                    t = nxt
            return tuple(out)

        # Configs whose walk plan AND chunk stream coincide perform
        # identical work (e.g. one walker over chunk-aligned shards): they
        # are one measurement shared across rows, so the reported curve
        # cannot show pure timer noise as a shard-count effect.
        ad_key = {s: (plans[s], chunk_stream(s)) for s in sweep}
        # Coverage checks double as warmup passes.
        assert sequential() == n, "sequential walk must cover every row"
        for s in sweep:
            assert sharded(s) == n, "sharded walk must cover every row"
            assert walked(s, *plans[s]) == n, "adaptive walk must cover every row"
        # Interleave the configs round-robin so slow drift (page cache,
        # CPU contention) lands on every config equally instead of biasing
        # whichever was measured last; keep the per-config minimum.
        uniq_ad = {ad_key[s]: s for s in sweep}
        best = {}
        for _ in range(iters):
            for key, fn in [("seq", sequential)] + [
                (("fixed", s), (lambda s=s: sharded(s))) for s in sweep
            ] + [
                (k, (lambda s=s: walked(s, *plans[s]))) for k, s in uniq_ad.items()
            ]:
                dt = _timed(fn)
                best[key] = min(best.get(key, dt), dt)
        t_seq = best["seq"]
        for shards in sweep:
            walkers, depth = plans[shards]
            t = best[("fixed", shards)]
            t_ad = best[ad_key[shards]]
            rows.append(
                {
                    "n": n,
                    "p": p,
                    "d": d,
                    "k": k,
                    "chunk": chunk,
                    "shards": shards,
                    "walkers": walkers,
                    "prefetch_depth": depth,
                    "sequential_ms": round(t_seq * 1e3, 3),
                    "sharded_ms": round(t * 1e3, 3),
                    "adaptive_ms": round(t_ad * 1e3, 3),
                    "speedup_vs_sequential": round(t_seq / t, 2),
                    "adaptive_speedup": round(t_seq / t_ad, 2),
                }
            )
            print(
                f"shard_sweep n={n} shards={shards}: sequential {t_seq * 1e3:8.2f} ms  "
                f"fixed {t * 1e3:8.2f} ms ({t_seq / t:.2f}x)  "
                f"adaptive[w={walkers} depth={depth}] {t_ad * 1e3:8.2f} ms ({t_seq / t_ad:.2f}x)"
            )
    finally:
        os.remove(path)
    return rows


# -------------------------------------------------------- regression gate
# `--smoke` doubles as the CI bench gate: the fresh run is compared
# against the committed BENCH_hotpath.json and the process exits nonzero
# when any section's higher-is-better aggregate regresses by more than
# 25%. The new report is still written first, so the uploaded artifact
# always reflects the run that was judged.
GATE_THRESHOLD = 0.75


def collect_gate_metric(section):
    """Geometric mean of every higher-is-better field (a name containing
    'speedup', or 'ratio') across a section's rows; None when the section
    has no such fields (e.g. chunk_sweep reports only overheads)."""
    rows = []
    if isinstance(section, dict):
        for v in section.values():
            if isinstance(v, list):
                rows.extend(r for r in v if isinstance(r, dict))
    elif isinstance(section, list):
        rows = [r for r in section if isinstance(r, dict)]
    vals = []
    for r in rows:
        for key, v in r.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            if ("speedup" in key or key == "ratio") and v > 0:
                vals.append(float(v))
    if not vals:
        return None
    return float(np.exp(np.mean(np.log(vals))))


def gate_against_baseline(report, baseline):
    """Per-section comparison vs the committed report; returns the list
    of regressed section names. Incomparable baselines (different mode or
    harness, missing or metric-less sections) are skipped loudly, never
    failed — the gate only judges like against like."""
    if not baseline:
        print("[gate] no committed BENCH_hotpath.json — gate skipped")
        return []
    if (baseline.get("mode"), baseline.get("harness")) != (
        report.get("mode"),
        report.get("harness"),
    ):
        print(
            f"[gate] baseline is {baseline.get('harness')}/{baseline.get('mode')}, "
            f"this run is {report.get('harness')}/{report.get('mode')} — gate skipped"
        )
        return []
    failures = []
    for name in (
        "pool_dispatch",
        "sq_dists",
        "simd_dispatch",
        "eig",
        "argmin_k",
        "chunk_sweep",
        "shard_sweep",
        "net",
    ):
        old = collect_gate_metric(baseline.get(name))
        new = collect_gate_metric(report.get(name))
        if old is None or new is None:
            print(f"[gate] {name}: no comparable higher-is-better metrics — skipped")
            continue
        ok = new >= GATE_THRESHOLD * old
        print(
            f"[gate] {name}: baseline {old:.2f} -> current {new:.2f} "
            f"({new / old:.0%}) {'ok' if ok else 'REGRESSION (<75%)'}"
        )
        if not ok:
            failures.append(name)
    return failures


def main():
    smoke = "--smoke" in sys.argv[1:]
    baseline_path = os.path.join(REPO_ROOT, "BENCH_hotpath.json")
    baseline = None
    if smoke and os.path.exists(baseline_path):
        try:
            with open(baseline_path) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[gate] unreadable baseline ({e}) — gate skipped")
    report = {
        "harness": "python-mirror",
        "mode": "smoke" if smoke else "full",
        "note": (
            "No Rust toolchain in this container; numbers mirror the rust "
            "hot-path transformations at the same shapes. `cargo bench "
            "--bench micro_hotpath` overwrites this file with native numbers."
        ),
        "threads": NT,
        "pool_dispatch": bench_dispatch(smoke),
        "sq_dists": bench_sq_dists(smoke),
        "simd_dispatch": bench_simd_dispatch(smoke),
        "eig": bench_eig(smoke),
        "argmin_k": bench_argmin(smoke),
        "chunk_sweep": bench_chunk_sweep(smoke),
        "shard_sweep": bench_shard_sweep(smoke),
        "net": bench_net(smoke),
    }
    with open(baseline_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[saved {baseline_path}]")
    if smoke:
        failures = gate_against_baseline(report, baseline)
        if failures:
            print(f"[gate] FAILED: {', '.join(failures)} regressed >25% vs the committed report")
            sys.exit(1)


if __name__ == "__main__":
    main()
