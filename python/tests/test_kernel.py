"""L1 correctness: the Pallas pdist kernel against the pure-jnp oracle,
swept over shapes and value ranges with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.pdist import pdist2, vmem_bytes, mxu_utilization, DEFAULT_BLOCK_M
from compile.kernels.ref import pdist2_ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128]),
    nblocks=st.integers(1, 3),
    cn=st.integers(1, 70),
    d=st.sampled_from([1, 2, 3, 16, 33]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pdist2_matches_ref(bm, nblocks, cn, d, seed):
    n = bm * nblocks
    x = rand((n, d), seed)
    c = rand((cn, d), seed + 1)
    got = pdist2(x, c, block_m=bm)
    want = pdist2_ref(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 2**31 - 1))
def test_pdist2_value_ranges(scale, seed):
    x = rand((128, 8), seed, scale)
    c = rand((16, 8), seed + 1, scale)
    got = np.asarray(pdist2(x, c))
    want = np.asarray(pdist2_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale * scale)
    assert (got >= 0).all(), "distances must be clamped at 0"


def test_pdist2_identity_rows_zero():
    x = rand((128, 5), 7)
    d2 = np.asarray(pdist2(x, x[:32]))
    # diagonal of the first 32 rows ≈ 0
    for i in range(32):
        assert d2[i, i] < 1e-4


def test_pdist2_rejects_ragged_batch():
    x = rand((100, 4), 3)  # not a multiple of block_m
    c = rand((8, 4), 4)
    with pytest.raises(AssertionError):
        pdist2(x, c, block_m=DEFAULT_BLOCK_M)


def test_vmem_estimate_within_budget():
    # The largest compiled variant must fit the 16 MB/core VMEM budget.
    assert vmem_bytes(128, 256, 784) < 16 * 1024 * 1024


def test_mxu_utilization_reasonable():
    # d=784, C=256 tiles densely; d=2 wastes lanes (documented in DESIGN.md)
    assert mxu_utilization(128, 256, 784) > 0.9
    assert mxu_utilization(128, 64, 2) < 0.3
