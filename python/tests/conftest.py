"""Test environment shims.

* Puts `python/` on `sys.path` so `from compile import …` resolves without
  an editable install.
* Gates the property-based suites on `hypothesis` being importable — the
  offline runtime image bakes in JAX but not hypothesis; those modules
  skip (rather than error at collection) when it is absent.
"""

import os
import sys

import pytest

PYDIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if PYDIR not in sys.path:
    sys.path.insert(0, PYDIR)

_NEEDS_HYPOTHESIS = {"test_kernel.py", "test_model.py"}


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running artifact emission tests")


def pytest_ignore_collect(collection_path, config):
    if collection_path.name in _NEEDS_HYPOTHESIS:
        try:
            import hypothesis  # noqa: F401
        except ImportError:
            return True
    return None
