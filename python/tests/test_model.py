"""L2 correctness: the fused graphs (dist_top1 / dist_topk) against the
pure-jnp oracles, including the center validity mask used for padding."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    cn=st.integers(2, 64),
    valid_n=st.integers(1, 64),
    d=st.sampled_from([2, 7, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dist_top1_masks_padding(cn, valid_n, d, seed):
    valid_n = min(valid_n, cn)
    x = rand((128, d), seed)
    c = rand((cn, d), seed + 1)
    valid = jnp.asarray((np.arange(cn) < valid_n).astype(np.float32))
    idx, dist = model.dist_top1_graph(x, c, valid)
    ridx, rdist = ref.kmeans_assign_ref(x, c, valid)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=2e-4, atol=2e-4)
    assert (np.asarray(idx) < valid_n).all(), "winner must be a valid center"


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 6),
    cn=st.integers(8, 64),
    d=st.sampled_from([2, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dist_topk_matches_ref(k, cn, d, seed):
    x = rand((128, d), seed)
    c = rand((cn, d), seed + 1)
    valid = jnp.ones((cn,), jnp.float32)
    idx, d2 = model.dist_topk_graph(x, c, valid, k=k)
    ridx, rd2 = ref.dist_topk_ref(x, c, k)
    # distances must match exactly as sets (ties can permute indices)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=2e-4, atol=2e-4)
    # ascending
    d2 = np.asarray(d2)
    assert (np.diff(d2, axis=1) >= -1e-5).all()


def test_topk_excludes_masked_centers():
    x = rand((128, 4), 1)
    c = rand((16, 4), 2)
    valid = jnp.asarray(([1.0] * 8 + [0.0] * 8), dtype=jnp.float32)
    idx, _ = model.dist_topk_graph(x, c, valid, k=5)
    assert (np.asarray(idx) < 8).all()


def test_lower_variant_shapes():
    for name, k in [("pdist", None), ("dist_top1", None), ("dist_topk", 5)]:
        lowered, inputs = model.lower_variant(name, 256, 64, 16, k)
        text = lowered.as_text()
        assert len(text) > 0
        assert inputs[0] == "x"
