"""AOT emission: HLO text parses structural expectations and the manifest
freshness check is a true no-op on second run."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model

HERE = os.path.dirname(os.path.abspath(__file__))
PYDIR = os.path.dirname(HERE)


def test_hlo_text_emission_small():
    lowered, _ = model.lower_variant("pdist", 256, 64, 16, None)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[256,64]" in text  # output shape appears in the module


def test_variant_names_unique():
    names = [aot.variant_name(g, b, c, d, k) for (g, b, c, d, k) in aot.variants()]
    assert len(names) == len(set(names))
    assert "pdist_b2048_c64_d2" in names
    assert "dist_topk_b2048_c64_d784_k5" in names


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()


@pytest.mark.slow
def test_aot_noop_when_fresh(tmp_path):
    # Emit a single-variant manifest by monkeypatching the grid (full run is
    # exercised by `make artifacts`); then verify the freshness short-circuit.
    out = str(tmp_path)
    env = dict(os.environ, PYTHONPATH=PYDIR)
    script = (
        "import compile.aot as a, sys;"
        "a.DIMS=[2]; a.PDIST_CENTERS=[64]; a.BATCH=256;"
        f"sys.argv=['aot','--out','{out}'];"
        "sys.exit(a.main())"
    )
    r1 = subprocess.run([sys.executable, "-c", script], env=env, cwd=PYDIR, capture_output=True, text=True)
    assert r1.returncode == 0, r1.stderr
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert len(manifest["artifacts"]) == 3  # pdist, dist_top1, dist_topk
    r2 = subprocess.run([sys.executable, "-c", script], env=env, cwd=PYDIR, capture_output=True, text=True)
    assert r2.returncode == 0, r2.stderr
    assert "fresh" in r2.stdout
