//! Robustness study (the paper's motivation for U-SENC, §3.2): run U-SPEC
//! and U-SENC across many seeds on a noisy nonlinear dataset and compare
//! the score distributions — U-SENC trades a m× time factor for a tighter,
//! higher distribution.
//!
//!     cargo run --release --example ensemble_robustness

use uspec::affinity::NativeBackend;
use uspec::data::Benchmark;
use uspec::metrics::nmi;
use uspec::usenc::{usenc, UsencParams};
use uspec::uspec::{uspec, UspecParams};

fn summarize(name: &str, scores: &[f64]) {
    let n = scores.len() as f64;
    let mean = scores.iter().sum::<f64>() / n;
    let std = (scores.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n).sqrt();
    let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("{name:8} NMI mean={mean:.4} std={std:.4} min={min:.4}  ({scores:.3?})");
}

fn main() {
    let trials = 8;
    let ds = Benchmark::Sf2m.generate(0.002, 3); // smiling face, 4000 pts
    println!("dataset {} n={} k={}", ds.name, ds.n(), ds.k);

    let base = UspecParams { k: ds.k, p: 400, ..Default::default() };
    let mut uspec_scores = Vec::new();
    let mut usenc_scores = Vec::new();
    for seed in 0..trials {
        let us = uspec(&ds.x, &base, seed).unwrap();
        uspec_scores.push(nmi(&us.labels, &ds.y));
        let ue = usenc(
            &ds.x,
            &UsencParams { k: ds.k, m: 10, k_min: 10, k_max: 30, base: base.clone() },
            seed,
            &NativeBackend,
        )
        .unwrap();
        usenc_scores.push(nmi(&ue.labels, &ds.y));
    }
    summarize("U-SPEC", &uspec_scores);
    summarize("U-SENC", &usenc_scores);

    let std = |v: &[f64]| {
        let m = v.iter().sum::<f64>() / v.len() as f64;
        (v.iter().map(|s| (s - m) * (s - m)).sum::<f64>() / v.len() as f64).sqrt()
    };
    println!(
        "\nrobustness: U-SENC std {:.4} vs U-SPEC std {:.4} ({})",
        std(&usenc_scores),
        std(&uspec_scores),
        if std(&usenc_scores) <= std(&uspec_scores) { "tighter — as in Tables 4-5" } else { "looser on this draw" }
    );
}
