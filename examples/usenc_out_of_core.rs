//! Out-of-core **U-SENC**: run the full ensemble — m diverse U-SPEC base
//! clusterers + bipartite consensus — over a dataset that lives on disk,
//! never materializing the N×d matrix in memory.
//!
//! The staged engine (`uspec::pipeline`) makes this cheap in disk passes
//! too: the m per-clusterer candidate sweeps share **one** sequential
//! read of the file, and each base clusterer then streams one KNR pass
//! (1 + m passes total instead of 2m). For a fixed seed, the labels are
//! bit-identical to the in-memory run.
//!
//!     cargo run --release --example usenc_out_of_core

use uspec::affinity::NativeBackend;
use uspec::data::Benchmark;
use uspec::metrics::nmi;
use uspec::pipeline::ExecOpts;
use uspec::streaming::{stream_usenc, BinDataset};
use uspec::usenc::{usenc, UsencParams};
use uspec::uspec::UspecParams;

fn main() {
    // Generate a slice of CC-5M and spill it to the on-disk format (in a
    // real deployment the file is produced by an ingest job).
    let ds = Benchmark::Cc5m.generate(0.002, 7); // 10k points, 3 rings
    let path = std::env::temp_dir().join("uspec_usenc_ooc.bin");
    let bin = BinDataset::write_mat(&path, &ds.x).expect("spill to disk");
    let file_mb = (24 + bin.n() * bin.d() * 4) as f64 / 1e6;
    println!("on-disk dataset: n={} d={} ({file_mb:.1} MB)", bin.n(), bin.d());

    let params = UsencParams {
        k: ds.k,
        m: 8,
        k_min: 6,
        k_max: 18,
        base: UspecParams { p: 300, ..Default::default() },
    };

    // Out-of-core: 2048-row chunks, two row-range shards walking the file
    // concurrently (each prefetching its next chunk while computing) —
    // resident working set is shards × chunk buffers + per-clusterer
    // candidates/index, independent of N·d. Shards never change labels.
    let opts = ExecOpts { chunk: 2048, shards: 2 };
    let t0 = std::time::Instant::now();
    let ooc = stream_usenc(&bin, &params, opts, 42, &NativeBackend).expect("stream usenc");
    let ooc_secs = t0.elapsed().as_secs_f64();
    println!(
        "out-of-core U-SENC (m={}, chunk={}, shards={}): {ooc_secs:.2}s  NMI={:.4}",
        params.m,
        opts.chunk,
        opts.shards,
        nmi(&ooc.labels, &ds.y)
    );

    // Same engine, resident source: identical labels for the same seed.
    let t1 = std::time::Instant::now();
    let mem = usenc(&ds.x, &params, 42, &NativeBackend).expect("in-memory usenc");
    let mem_secs = t1.elapsed().as_secs_f64();
    println!(
        "in-memory  U-SENC (same seed):           {mem_secs:.2}s  NMI={:.4}",
        nmi(&mem.labels, &ds.y)
    );
    assert_eq!(ooc.labels, mem.labels, "one engine, one answer");
    println!("labels bit-identical across sources ✓");

    std::fs::remove_file(&path).ok();
}
