//! Model selection + adaptive ensembles — the "no ground truth" workflow.
//!
//! The paper's evaluation fixes k to the true class count (§4.2). In
//! deployment k is unknown; this example shows the two extensions that
//! close the loop:
//!
//!   1. `estimate_k`: probe the transfer-cut spectrum once and read k off
//!      the relative eigengap.
//!   2. `usenc_adaptive`: grow the U-SPEC ensemble only until the
//!      consensus stabilizes, instead of a fixed m = 20.
//!
//!     cargo run --release --example auto_k

use uspec::affinity::NativeBackend;
use uspec::data::synthetic::{concentric_circles, smiling_face, two_moons};
use uspec::metrics::nmi;
use uspec::usenc::adaptive::{usenc_adaptive, AdaptiveParams};
use uspec::usenc::UsencParams;
use uspec::uspec::estimate::estimate_k;
use uspec::uspec::UspecParams;

fn main() {
    let datasets = [
        ("two moons", two_moons(3000, 0.05, 7), 2usize),
        ("concentric circles", concentric_circles(3000, 9), 3),
        ("smiling face", smiling_face(3000, 5), 4),
    ];

    for (name, ds, true_k) in datasets {
        // --- 1. estimate k from the eigengap (no labels used) ------------
        let base = UspecParams { p: 400.min(ds.n() / 4), ..Default::default() };
        let est = estimate_k(&ds.x, &base, 2, 10, 11, &NativeBackend)
            .expect("estimate_k");
        println!(
            "{name}: true k = {true_k}, eigengap estimate = {} (gap {:.2e})",
            est.k, est.gap
        );

        // --- 2. cluster at the estimated k with an adaptive ensemble -----
        let params = UsencParams {
            k: est.k,
            m: 40, // ceiling only; the adaptive loop stops early
            k_min: 8,
            k_max: 20,
            base,
        };
        let t0 = std::time::Instant::now();
        let res = usenc_adaptive(
            &ds.x,
            &params,
            &AdaptiveParams::default(),
            42,
            &NativeBackend,
        )
        .expect("usenc_adaptive");
        println!(
            "  adaptive U-SENC: m = {} ({}), NMI vs truth = {:.4}, {:.2}s",
            res.ensemble.m(),
            if res.converged { "converged" } else { "hit ceiling" },
            nmi(&res.labels, &ds.y),
            t0.elapsed().as_secs_f64(),
        );
        println!("  consensus stability trace: {:?}",
            res.stability_trace.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    }
}
