//! Streaming / out-of-core usage: fit U-SPEC on a head sample, then label
//! an unbounded stream of arriving batches in O(batch · K · d) each via the
//! fitted representative graph — the deployment pattern for ten-million-
//! scale data that cannot be held in memory at once.
//!
//!     cargo run --release --example streaming_pipeline

use uspec::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bipartite::{transfer_cut, EigSolver};
use uspec::data::Benchmark;
use uspec::kmeans::{kmeans, KmeansParams};
use uspec::metrics::nmi;

fn main() {
    // "Head" sample: 20k points of Flower-20M used to fit the model.
    let head = Benchmark::Flower20m.generate(0.001, 3);
    let k = head.k;
    println!("fit on head sample: n={} k={k}", head.n());

    // Fit: representatives -> KNR index -> bipartite partition.
    let p = 1000.min(head.n() / 2);
    let reps =
        select(&head.x, SelectStrategy::Hybrid { candidate_factor: 10 }, p, 30, 7).unwrap();
    let index = KnrIndex::build(&reps, 50, 30, &NativeBackend).unwrap();
    let knr = index.approx_knr(&head.x, 5, &NativeBackend);
    let aff = build_affinity(head.n(), index.p(), knr.k, &knr);
    let tc = transfer_cut(&aff.b, k, EigSolver::Auto, 11).unwrap();
    let km = kmeans(&tc.embedding, &KmeansParams { k, ..Default::default() }, 13).unwrap();
    println!("head NMI = {:.4}", nmi(&km.labels, &head.y));

    // Representative → cluster map: majority vote of the objects selecting
    // each representative (gives a streaming classifier).
    let mut votes = vec![vec![0u32; k]; index.p()];
    for i in 0..head.n() {
        for &r in &knr.idx[i * knr.k..(i + 1) * knr.k] {
            votes[r as usize][km.labels[i] as usize] += 1;
        }
    }
    let rep_label: Vec<u32> = votes
        .iter()
        .map(|v| v.iter().enumerate().max_by_key(|(_, &c)| c).map(|(i, _)| i as u32).unwrap_or(0))
        .collect();

    // Stream: label arriving batches by nearest representative.
    let mut total = 0usize;
    let mut agree = 0usize;
    let t0 = std::time::Instant::now();
    for batch_id in 0..10u64 {
        let batch = Benchmark::Flower20m.generate(0.0005, 100 + batch_id); // 10k each
        let b_knr = index.approx_knr(&batch.x, 1, &NativeBackend);
        let labels: Vec<u32> =
            (0..batch.n()).map(|i| rep_label[b_knr.idx[i] as usize]).collect();
        let batch_nmi = nmi(&labels, &batch.y);
        total += batch.n();
        agree += labels
            .iter()
            .zip(&batch.y)
            .filter(|(a, b)| {
                // NMI handles permutation; raw agreement is only a proxy here
                let _ = b;
                **a < k as u32
            })
            .count();
        println!("batch {batch_id}: n={} streamed NMI={batch_nmi:.4}", batch.n());
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\nstreamed {total} objects in {secs:.2}s ({:.0} objects/s); labels valid for {agree}",
        total as f64 / secs
    );
}
