//! Out-of-core clustering: the dataset lives ON DISK and never fits in
//! memory at once. `stream_uspec` runs the paper's whole pipeline in two
//! sequential passes with a bounded resident set:
//!
//!   pass 1  reservoir-sample p′ candidates → k-means → p representatives
//!   pass 2  chunked approximate-KNR → sparse B (O(N·K)) → transfer cut
//!
//! The resident peak is O(N·K + chunk·d) — independent of N·d. For the
//! paper's MNIST shape (d=784, K=5) that is ~40× smaller than the data.
//!
//!     cargo run --release --example out_of_core

use uspec::affinity::NativeBackend;
use uspec::data::Benchmark;
use uspec::metrics::{ca, nmi};
use uspec::streaming::{stream_uspec, BinDataset, StreamParams};
use uspec::uspec::UspecParams;

fn main() {
    // Generate CG (circles + gaussians) at 50k points and spill it to disk
    // as the flat USPECB01 format — stand-in for a dataset produced by an
    // external ETL job.
    let ds = Benchmark::Cg10m.generate(0.005, 7);
    let dir = std::env::temp_dir().join("uspec_out_of_core");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cg.bin");
    let bin = BinDataset::write_mat(&path, &ds.x).expect("spill to disk");
    let file_bytes = std::fs::metadata(&path).unwrap().len();
    println!(
        "on-disk dataset: n={} d={} ({:.1} MB at {})",
        bin.n(),
        bin.d(),
        file_bytes as f64 / 1e6,
        path.display()
    );

    // Cluster it without ever materializing the full matrix: 4096-row
    // chunks stream through the fitted representative graph.
    let params = StreamParams {
        chunk: 4096,
        shards: 2, // two row ranges stream the file concurrently
        base: UspecParams { k: ds.k, p: 1000, ..Default::default() },
    };
    let t0 = std::time::Instant::now();
    let res = stream_uspec(&bin, &params, 42, &NativeBackend).expect("stream_uspec");
    let secs = t0.elapsed().as_secs_f64();

    println!("streamed U-SPEC: k={}", ds.k);
    println!("  NMI  = {:.4}", nmi(&res.labels, &ds.y));
    println!("  CA   = {:.4}", ca(&res.labels, &ds.y));
    println!("  time = {secs:.2}s  ({})", res.timer.summary());
    println!(
        "  resident model = {:.1} MB ({:.2}× the raw data; chunk={} rows)",
        res.peak_bytes as f64 / 1e6,
        res.peak_bytes as f64 / file_bytes as f64,
        params.chunk,
    );
    // At the paper's MNIST shape (d=784) the same resident model is
    // dominated by O(N·K) ≪ N·d — the scaling that lets a 64 GB PC hold
    // the pipeline for a dataset it cannot hold densely.
    let (n, d, _) = Benchmark::Mnist.paper_shape();
    let resident = (n * 5) as f64 * 20.0 + 4096.0 * d as f64 * 4.0;
    let dense = (n * d) as f64 * 4.0;
    println!(
        "  at MNIST shape (d=784): resident/dense ≈ {:.3} (model)",
        resident / dense
    );

    let _ = std::fs::remove_file(&path);
}
