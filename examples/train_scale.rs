//! End-to-end scale driver (EXPERIMENTS.md §End-to-end): runs the full
//! three-layer system — rust coordinator, PJRT kernel pool serving the
//! AOT-compiled Pallas distance kernel, U-SPEC and U-SENC — on a real
//! workload: the paper's CG (circles+gaussians) shape at 100k–200k
//! objects, reporting the headline metrics (NMI/CA, objects/s, kernel
//! dispatch stats) per stage.
//!
//!     cargo run --release --example train_scale [scale]
//!
//! `scale` is the fraction of CG-10M to generate (default 0.01 → 100k).

use uspec::coordinator::usenc_coordinated;
use uspec::data::Benchmark;
use uspec::metrics::{ca, nmi};
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend};
use uspec::usenc::UsencParams;
use uspec::uspec::{uspec_with_backend, UspecParams};

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.01);
    let ds = Benchmark::Cg10m.generate(scale, 7);
    println!(
        "workload: {} at scale {scale} -> n={} d={} k={}",
        ds.name,
        ds.n(),
        ds.d(),
        ds.k
    );

    // Kernel pool over the AOT artifacts (falls back to native when absent).
    let art = default_artifact_dir();
    let (backend, pool): (Box<dyn uspec::affinity::DistanceBackend>, _) =
        if art.join("manifest.json").exists() {
            let pool = KernelPool::start(&art).expect("kernel pool");
            (Box::new(PjrtBackend::new(pool.clone())), Some(pool))
        } else {
            eprintln!("NOTE: artifacts/ missing — run `make artifacts` for the PJRT path");
            (Box::new(uspec::affinity::NativeBackend), None)
        };

    // ---- Stage 1: single U-SPEC clusterer --------------------------------
    let params = UspecParams { k: ds.k, p: 1000.min(ds.n() / 2), ..Default::default() };
    let t0 = std::time::Instant::now();
    let res = uspec_with_backend(&ds.x, &params, 42, backend.as_ref()).expect("u-spec");
    let t_uspec = t0.elapsed().as_secs_f64();
    println!(
        "\nU-SPEC : NMI={:.4} CA={:.4}  {:.2}s ({:.0} objects/s)",
        nmi(&res.labels, &ds.y),
        ca(&res.labels, &ds.y),
        t_uspec,
        ds.n() as f64 / t_uspec
    );
    println!("  phases: {}", res.timer.summary());

    // ---- Stage 2: U-SENC ensemble through the coordinator ----------------
    let usenc_params = UsencParams {
        k: ds.k,
        m: 8,
        k_min: 20.min(ds.n() / 4),
        k_max: 40.min(ds.n() / 2),
        base: params.clone(),
    };
    let t0 = std::time::Instant::now();
    let ens = usenc_coordinated(
        &ds.x,
        &usenc_params,
        42,
        backend.as_ref(),
        uspec::util::par::num_threads(),
        Some(&|done, total| eprintln!("  base clusterer {done}/{total} done")),
    )
    .expect("u-senc");
    let t_usenc = t0.elapsed().as_secs_f64();
    println!(
        "U-SENC : NMI={:.4} CA={:.4}  {:.2}s ({:.0} objects/s, m={})",
        nmi(&ens.labels, &ds.y),
        ca(&ens.labels, &ds.y),
        t_usenc,
        ds.n() as f64 / t_usenc,
        usenc_params.m
    );
    println!("  phases: {}", ens.timer.summary());

    if let Some(pool) = pool {
        let (dispatched, rows) = pool.stats();
        println!(
            "\nkernel pool: {dispatched} dispatches, {rows} rows through the Pallas pdist artifact, {} coalesced",
            pool.coalesced.load(std::sync::atomic::Ordering::Relaxed)
        );
    }
}
