//! Quickstart: cluster a nonlinearly separable dataset with U-SPEC in a
//! dozen lines.
//!
//!     cargo run --release --example quickstart

use uspec::data::synthetic::two_moons;
use uspec::metrics::{ca, nmi};
use uspec::uspec::{uspec, UspecParams};

fn main() {
    // 5,000 points on two interleaved moons — k-means cannot separate
    // these; spectral clustering can.
    let ds = two_moons(5_000, 0.06, 7);

    let params = UspecParams {
        k: 2,    // clusters
        p: 500,  // representatives (paper default: 1000)
        k_nn: 5, // K nearest representatives per object
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let res = uspec(&ds.x, &params, 42).expect("u-spec failed");
    let secs = t0.elapsed().as_secs_f64();

    println!("U-SPEC on two moons (n={}, d={}):", ds.n(), ds.d());
    println!("  NMI  = {:.4}", nmi(&res.labels, &ds.y));
    println!("  CA   = {:.4}", ca(&res.labels, &ds.y));
    println!("  time = {secs:.3}s   ({})", res.timer.summary());

    // Compare with plain k-means — the motivation for the whole paper.
    let km = uspec::kmeans::kmeans(
        &ds.x,
        &uspec::kmeans::KmeansParams { k: 2, ..Default::default() },
        42,
    )
    .unwrap();
    println!("  k-means NMI = {:.4} (for contrast)", nmi(&km.labels, &ds.y));
}
