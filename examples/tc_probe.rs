// perf probe: where does transfer_cut spend time at p=1000?
use uspec::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bipartite::{transfer_cut, EigSolver};
use uspec::data::Benchmark;

fn main() {
    let ds = Benchmark::Cg10m.generate(0.01, 7); // 100k
    let t0 = std::time::Instant::now();
    let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 1000, 100, 1).unwrap();
    println!("select: {:.2}s", t0.elapsed().as_secs_f64());
    let index = KnrIndex::build(&reps, 50, 30, &NativeBackend).unwrap();
    let knr = index.approx_knr(&ds.x, 5, &NativeBackend);
    let aff = build_affinity(ds.n(), index.p(), knr.k, &knr);
    for solver in [EigSolver::Auto, EigSolver::Dense] {
        let t0 = std::time::Instant::now();
        let tc = transfer_cut(&aff.b, 11, solver, 3).unwrap();
        println!("{:?}: {:.3}s  lambdas={:?}", solver, t0.elapsed().as_secs_f64(), &tc.lambdas[..4]);
    }
}
